"""Flat parameter planes: layout, parity, launch counts, state round-trips.

The tentpole claims pinned here (fast tier; the shard_map side lives in
tests/test_distributed.py::test_flat_planes_shard_map_parity_and_collective_count):

* pack/unpack is a lossless round trip for mixed-dtype trees, per-node and
  stacked, and both pack lowerings produce identical buffers;
* the plane path is **bit-exact** with the per-leaf path for all 11
  algorithms — on the stacked reference executor (real gossip channel) and
  on the Pallas stage executor (interpret mode), including LARS row
  scalars, grad clip, weight decay and staleness damping;
* the plane Pallas path issues exactly O(dtype-buckets x stages)
  ``pallas_call``s where the per-leaf path issues O(leaves x stages) —
  counted from the traced jaxpr;
* plane-layout channel state (delay ring buffers, error feedback)
  checkpoints and resumes bit-exactly, and ``reconcile_plane_state``
  converts optimizer state across the ``flat_planes`` flag.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StackedChannel, build_topology, make_stacked_mean
from repro.core.gossip import DelayedStackedChannel
from repro.core.optimizers import ALGORITHMS, OptimizerConfig, make_optimizer
from repro.core.planes import LANES, PlaneLayout, plane_scalars
from repro.core.update_spec import run_update, stage_plan, update_spec
from repro.kernels.fused_update import make_plane_stage, make_stage
from repro.launch.costmodel import count_primitive

RNG = np.random.default_rng(11)


def _tmpl():
    return {
        "w1": jnp.asarray(RNG.standard_normal((13, 7)), jnp.float32),
        "w2": jnp.asarray(RNG.standard_normal((2000,)), jnp.bfloat16),
        "emb": jnp.asarray(RNG.standard_normal((40, 33)), jnp.bfloat16),
        "ln": jnp.asarray(RNG.standard_normal((9,)), jnp.float32),
        "b": jnp.asarray(RNG.standard_normal(()), jnp.float32),
    }


def _rand_like(tree, dtype=None):
    return jax.tree.map(
        lambda a: jnp.asarray(
            RNG.standard_normal(a.shape), dtype if dtype is not None else a.dtype
        ),
        tree,
    )


def _tree_equal(a, b) -> bool:
    return all(
        jax.tree.leaves(jax.tree.map(lambda p, q: bool(jnp.array_equal(p, q)), a, b))
    )


# ---------------------------------------------------------------------------
# layout mechanics
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip_mixed_dtype():
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    assert set(lay.segments) == {"float32", "bfloat16"}
    planes = lay.pack(tmpl)
    for key, buf in planes.items():
        assert buf.shape == (lay.rows[key], LANES)
        assert buf.dtype == jnp.dtype(key)
    assert _tree_equal(lay.unpack(planes, like=tmpl), tmpl)
    # leaves are row-aligned: no row belongs to two segments
    for key, segs in lay.segments.items():
        spans = sorted((s.row_start, s.row_start + s.rows) for s in segs)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0


def test_pack_impls_identical_and_stacked_roundtrip():
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    stacked = jax.tree.map(
        lambda a: jnp.asarray(
            RNG.standard_normal((3,) + a.shape), a.dtype
        ),
        tmpl,
    )
    for leading, tree in ((0, tmpl), (1, stacked)):
        a = lay.pack(tree, leading=leading, impl="concat")
        b = lay.pack(tree, leading=leading, impl="gather")
        assert _tree_equal(a, b)
        assert _tree_equal(lay.unpack(b, like=tree, leading=leading), tree)
    # f32 cast pack (gradient/momentum trees)
    g = _rand_like(tmpl, jnp.float32)
    gp = lay.pack(g, dtype=jnp.float32)
    assert all(v.dtype == jnp.float32 for v in gp.values())
    assert _tree_equal(lay.unpack(gp, dtype=jnp.float32), g)


def test_row_scalars_scatter():
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    scalars = {k: float(i + 2) for i, k in enumerate(sorted(tmpl))}
    cols = lay.row_scalars(scalars)
    for key, segs in lay.segments.items():
        col = np.asarray(cols[key])
        assert col.shape == (lay.rows[key], 1)
        names = sorted(tmpl)
        leaf_order = [names[i] for i in range(len(names))]
        for seg in segs:
            want = scalars[leaf_order[seg.index]]
            got = col[seg.row_start: seg.row_start + seg.rows, 0]
            np.testing.assert_array_equal(got, want)


def test_pallas_interpret_zero_pad_rows_inert():
    """A plane whose rows are not a multiple of the 64-row kernel block
    still computes the real rows exactly (boundary block masked)."""
    tmpl = {"w": jnp.asarray(RNG.standard_normal((70,)), jnp.float32)}
    lay = PlaneLayout.build(tmpl)
    cfg = OptimizerConfig(algorithm="decentlam", momentum=0.9)
    spec = update_spec(cfg)
    g = _rand_like(tmpl, jnp.float32)
    state = make_optimizer(cfg).init(tmpl)

    def gossip(tree, step, comp):
        return jax.tree.map(lambda a: 0.5 * a, tree), comp

    kw = dict(lr=0.01, step_idx=jnp.int32(0), gossip=gossip, mean=lambda t: t,
              comp_state=())
    x1, s1, _ = run_update(spec, cfg, x=tmpl, g=g, state=state,
                           stage=make_stage("pallas_interpret"), **kw)
    xp = lay.pack(tmpl)
    x2p, _, _ = run_update(
        spec, cfg, x=xp, g=lay.pack(g, dtype=jnp.float32),
        state={k: lay.pack(v, dtype=jnp.float32) for k, v in state.items()},
        stage=make_plane_stage("pallas_interpret"),
        scalars=plane_scalars(cfg, lay, tmpl, g), **kw,
    )
    assert _tree_equal(x1, lay.unpack(x2p, like=tmpl))


# ---------------------------------------------------------------------------
# plane-vs-per-leaf parity: all 11 algorithms, bit-exact
# ---------------------------------------------------------------------------

CONFIGS = (
    {},
    {"lars": True, "weight_decay": 0.01, "grad_clip": 1.0},
)


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("extras", CONFIGS, ids=("plain", "lars-clip-wd"))
def test_plane_parity_reference_stacked(algo, extras):
    """Stacked reference path with a real gossip channel: the packed update
    equals the per-leaf update bit-for-bit over multiple steps."""
    n = 4
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    topo = build_topology("ring", n)
    chan, mean = StackedChannel(topo), make_stacked_mean(n)
    cfg = OptimizerConfig(algorithm=algo, momentum=0.9, **extras)
    spec = update_spec(cfg)
    opt = make_optimizer(cfg)

    x = jax.tree.map(
        lambda a: jnp.asarray(RNG.standard_normal((n,) + a.shape), a.dtype), tmpl
    )
    state = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape),
        opt.init(jax.tree.map(lambda a: a[0], x)),
    )
    xp = lay.pack(x, leading=1)
    state_pl = {
        k: lay.pack(v, dtype=jnp.float32, leading=1) for k, v in state.items()
    }
    comp = chan.init(x)
    comp_pl = chan.init(xp)
    for k in range(2):
        g = jax.tree.map(
            lambda a: jnp.asarray(RNG.standard_normal(a.shape), jnp.float32), x
        )
        kw = dict(lr=0.01, step_idx=jnp.int32(k), gossip=chan, mean=mean)
        sc = plane_scalars(cfg, lay, x, g)  # from the pre-update trees
        x1, state, comp = run_update(
            spec, cfg, x=x, g=g, state=state, comp_state=comp, **kw
        )
        x = jax.tree.map(lambda p, v: v.astype(p.dtype), x, x1)
        xp_new, state_pl, comp_pl = run_update(
            spec, cfg, x=xp, g=lay.pack(g, dtype=jnp.float32, leading=1),
            state=state_pl, comp_state=comp_pl, scalars=sc, **kw,
        )
        xp = jax.tree.map(lambda p, v: v.astype(p.dtype), xp, xp_new)
        assert _tree_equal(x, lay.unpack(xp, like=x, leading=1)), f"step {k}"
    for sk, v in state.items():
        assert _tree_equal(v, lay.unpack(state_pl[sk], dtype=jnp.float32,
                                         leading=1)), sk


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("extras", CONFIGS, ids=("plain", "lars-clip-wd"))
def test_plane_parity_pallas_interpret(algo, extras):
    """Per-node Pallas path: whole-plane stage kernels equal the per-leaf
    stage kernels bit-for-bit (incl. the LARS row-scalar operand and the
    staleness damping scalar)."""
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    cfg = OptimizerConfig(algorithm=algo, momentum=0.9, **extras)
    spec = update_spec(cfg)
    x = _rand_like(tmpl)
    g = _rand_like(tmpl, jnp.float32)
    state = make_optimizer(cfg).init(x)

    def gossip(tree, step, comp):
        return jax.tree.map(lambda a: 0.7 * a, tree), comp

    ng = jnp.int32(2) if spec.staleness_aware else None
    kw = dict(lr=0.01, step_idx=jnp.int32(3), gossip=gossip, mean=lambda t: t,
              comp_state=(), node_gaps=ng)
    x1, s1, _ = run_update(spec, cfg, x=x, g=g, state=state,
                           stage=make_stage("pallas_interpret"), **kw)
    x2p, s2p, _ = run_update(
        spec, cfg, x=lay.pack(x), g=lay.pack(g, dtype=jnp.float32),
        state={k: lay.pack(v, dtype=jnp.float32) for k, v in state.items()},
        stage=make_plane_stage("pallas_interpret"),
        scalars=plane_scalars(cfg, lay, x, g), **kw,
    )
    assert _tree_equal(x1, lay.unpack(x2p, like=x))
    for sk in s1:
        assert _tree_equal(s1[sk], lay.unpack(s2p[sk], dtype=jnp.float32)), sk


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_plane_launch_count_is_O_stages(algo):
    """jaxpr-counted pallas_calls: per-leaf = leaves x stages, plane =
    buckets x stages — the tentpole's launch-collapse claim."""
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    cfg = OptimizerConfig(algorithm=algo, momentum=0.9, weight_decay=0.01)
    spec = update_spec(cfg)
    g = _rand_like(tmpl, jnp.float32)
    state = make_optimizer(cfg).init(tmpl)

    def gossip(tree, step, comp):
        return tree, comp

    kw = dict(lr=0.01, step_idx=jnp.int32(0), gossip=gossip, mean=lambda t: t,
              comp_state=())

    def leaf_fn(x, g, state):
        return run_update(spec, cfg, x=x, g=g, state=state,
                          stage=make_stage("pallas_interpret"), **kw)

    def plane_fn(x, g, state):
        return run_update(
            spec, cfg, x=lay.pack(x), g=lay.pack(g, dtype=jnp.float32),
            state={k: lay.pack(v, dtype=jnp.float32) for k, v in state.items()},
            stage=make_plane_stage("pallas_interpret"),
            scalars=plane_scalars(cfg, lay, tmpl, g), **kw,
        )

    stages = len(stage_plan(cfg))
    n_leaves = len(jax.tree.leaves(tmpl))
    n_buckets = len(lay.segments)
    assert count_primitive(
        jax.make_jaxpr(leaf_fn)(tmpl, g, state), "pallas_call"
    ) == n_leaves * stages
    assert count_primitive(
        jax.make_jaxpr(plane_fn)(tmpl, g, state), "pallas_call"
    ) == n_buckets * stages


# ---------------------------------------------------------------------------
# plane-layout channel state: checkpoint round trip + resume equality
# ---------------------------------------------------------------------------


def test_plane_channel_state_checkpoint_roundtrip(tmp_path):
    """A delayed channel whose state lives in plane layout (ring buffers of
    packed payloads + top-k error feedback) checkpoints through the npz
    store and resumes bit-exactly: interrupted == uninterrupted."""
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    n = 4
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    topo = build_topology("ring", n)
    chan = DelayedStackedChannel(topo, 2, compression="topk:0.2")
    cfg = OptimizerConfig(algorithm="decentlam-sa", momentum=0.8)
    spec = update_spec(cfg)
    opt = make_optimizer(cfg)

    x = jax.tree.map(
        lambda a: jnp.asarray(RNG.standard_normal((n,) + a.shape), a.dtype), tmpl
    )
    xp = lay.pack(x, leading=1)
    state_pl = {
        k: lay.pack(v, dtype=jnp.float32, leading=1)
        for k, v in jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape),
            opt.init(jax.tree.map(lambda a: a[0], x)),
        ).items()
    }
    comp_pl = chan.init(xp)
    grads = [
        lay.pack(
            jax.tree.map(
                lambda a: jnp.asarray(RNG.standard_normal(a.shape), jnp.float32), x
            ),
            dtype=jnp.float32, leading=1,
        )
        for _ in range(6)
    ]

    def step(xp, state_pl, comp_pl, k):
        xn, state_pl, comp_pl = run_update(
            spec, cfg, x=xp, g=grads[k], state=state_pl, lr=0.01,
            step_idx=jnp.int32(k), gossip=chan, mean=make_stacked_mean(n),
            comp_state=comp_pl,
        )
        return (
            jax.tree.map(lambda p, v: v.astype(p.dtype), xp, xn),
            state_pl, comp_pl,
        )

    # uninterrupted: 6 steps
    a_x, a_s, a_c = xp, state_pl, comp_pl
    for k in range(6):
        a_x, a_s, a_c = step(a_x, a_s, a_c, k)

    # interrupted at 3: checkpoint, restore, continue
    b_x, b_s, b_c = xp, state_pl, comp_pl
    for k in range(3):
        b_x, b_s, b_c = step(b_x, b_s, b_c, k)
    ckpt = {
        "step": jnp.int32(3),
        "params": b_x,
        "opt": b_s,
        "channel": b_c,
    }
    save_checkpoint(str(tmp_path), jax.device_get(ckpt))
    restored, _ = restore_checkpoint(str(tmp_path))
    assert _tree_equal(restored["channel"], b_c)  # delay rings + EF exact
    b_x, b_s, b_c = restored["params"], restored["opt"], restored["channel"]
    for k in range(3, 6):
        b_x, b_s, b_c = step(b_x, b_s, b_c, k)

    assert _tree_equal(a_x, b_x)
    assert _tree_equal(a_s, b_s)
    assert _tree_equal(a_c, b_c)


def test_reconcile_plane_state_roundtrip():
    """Optimizer state converts tree <-> plane across the flat_planes flag
    without loss (the cross-format resume path)."""
    from repro.train.train_state import reconcile_plane_state

    n = 3
    tmpl = _tmpl()
    lay = PlaneLayout.build(tmpl)
    m = jax.tree.map(
        lambda a: jnp.asarray(RNG.standard_normal((n,) + a.shape), jnp.float32),
        tmpl,
    )
    tree_state = {"step": jnp.int32(7), "params": {}, "opt": {"m": m}}
    packed = reconcile_plane_state(tree_state, lay, True)
    assert set(packed["opt"]["m"]) == set(lay.segments)
    # already-plane state passes through unchanged
    again = reconcile_plane_state(packed, lay, True)
    assert _tree_equal(again["opt"]["m"], packed["opt"]["m"])
    back = reconcile_plane_state(packed, lay, False)
    assert _tree_equal(back["opt"]["m"], m)


def _tp_cfg():
    """Tiny model whose dims divide at tp in {1, 2, 4} (vocab 256, heads 4)."""
    from repro.configs import tiny_lm

    return tiny_lm(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256,
    )


@pytest.mark.parametrize("tp", (1, 2, 4))
def test_model_plane_layout_tp_construction(tp):
    """``model_plane_layout`` accepts tp > 1 (the pre-sharding gate is
    gone): sharded segments carry local shapes (global dim / tp along the
    model axis named by ``param_specs``), replicated segments keep their
    global shape on every rank, and each rank's bucket row totals stay
    ROW_MULTIPLE-aligned (the fused kernel's bit-exactness invariant)."""
    from repro.core.planes import ROW_MULTIPLE, _shard_axis_of
    from repro.models import transformer as T
    from repro.train.train_state import model_plane_layout

    cfg = _tp_cfg()
    lay = model_plane_layout(cfg, tp)
    assert lay.tp == tp and lay.sharded == (tp > 1)
    for key, total in lay.rows.items():
        assert total % ROW_MULTIPLE == 0, key

    specs = (
        lay.treedef.flatten_up_to(T.param_specs(cfg, tp)) if tp > 1 else None
    )
    n_sharded = 0
    for segs in lay.segments.values():
        for seg in segs:
            if seg.shard_axis is None:
                assert seg.full_shape == seg.shape
            else:
                n_sharded += 1
                ax = seg.shard_axis
                assert seg.full_shape[ax] == seg.shape[ax] * tp
                assert (
                    seg.full_shape[:ax] == seg.shape[:ax]
                    and seg.full_shape[ax + 1:] == seg.shape[ax + 1:]
                )
                assert _shard_axis_of(specs[seg.index], "model") == ax
    if tp > 1:
        assert n_sharded > 0  # embed/attention/mlp leaves really shard
        # local template == what one mesh column materializes
        local = jax.tree.leaves(lay.local_template())
        glob = jax.tree.leaves(lay.global_template())
        assert sum(np.prod(l.shape) for l in local) < sum(
            np.prod(g.shape) for g in glob
        )
    else:
        assert n_sharded == 0
        assert _tree_equal(
            jax.tree.map(lambda a: a.shape, lay.local_template()),
            jax.tree.map(lambda a: a.shape, lay.global_template()),
        )


def test_sharded_build_rejects_bad_inputs():
    """tp > 1 without shardings and non-divisible sharded dims both fail
    loudly at build time (what used to be a blanket tp == 1 gate)."""
    from jax.sharding import PartitionSpec as P

    tmpl = {"w": jnp.zeros((6, 10), jnp.float32)}
    with pytest.raises(ValueError, match="shardings"):
        PlaneLayout.build(tmpl, tp=2)
    with pytest.raises(ValueError, match="not divisible"):
        PlaneLayout.build(
            {"w": jnp.zeros((7, 10), jnp.float32)},
            tp=2, shardings={"w": P("model", None)},
        )


# ---------------------------------------------------------------------------
# sharded pack_global/unpack_global property (satellite: hypothesis + sweep)
# ---------------------------------------------------------------------------

try:  # hypothesis is an optional [test] extra — the seeded sweep below
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    HAVE_HYPOTHESIS = False


def _random_sharded_case(seed: int, tp: int):
    """Random mixed-dtype tree + PartitionSpecs with every sharded dim
    divisible by ``tp`` (the generator behind both property tests)."""
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(seed)
    n_leaves = int(rng.integers(3, 8))
    tmpl, specs = {}, {}
    for i in range(n_leaves):
        ndim = int(rng.integers(0, 4))
        shape = tuple(int(rng.integers(1, 40)) for _ in range(ndim))
        dtype = jnp.float32 if rng.random() < 0.5 else jnp.bfloat16
        name = f"leaf{i}"
        if ndim and rng.random() < 0.6:
            ax = int(rng.integers(0, ndim))
            shape = (
                shape[:ax] + (tp * int(rng.integers(1, 12)),) + shape[ax + 1:]
            )
            entries = [None] * ndim
            entries[ax] = "model"
            specs[name] = P(*entries)
        else:
            specs[name] = P(*([None] * ndim)) if rng.random() < 0.5 else None
        tmpl[name] = jnp.asarray(
            rng.standard_normal(shape) if shape else rng.standard_normal(),
            dtype,
        )
    return tmpl, specs


def _check_sharded_roundtrip(seed: int, tp: int):
    """The sharded-layout contract on one random case:

    * ``unpack_global(pack_global(tree))`` is the identity (bit-exact,
      mixed dtypes, both the template-dtype and the f32-cast stacked path);
    * rank block ``r`` of ``pack_global`` equals ``pack`` of
      ``shard_slice(tree, r)`` — the local form every mesh column sees;
    * replicated leaves pack identically into every rank block.
    """
    tree, specs = _random_sharded_case(seed, tp)
    lay = PlaneLayout.build(tree, tp=tp, shardings=specs)
    assert lay.tp == tp

    planes = lay.pack_global(tree)
    for key, buf in planes.items():
        assert buf.shape == (tp * lay.rows[key], LANES)
    assert _tree_equal(lay.unpack_global(planes, like=tree), tree)

    for r in range(tp):
        local = lay.pack(lay.shard_slice(tree, r))
        block = {
            k: v[r * lay.rows[k]: (r + 1) * lay.rows[k]]
            for k, v in planes.items()
        }
        assert _tree_equal(local, block), f"rank {r}"

    # replicated leaves: every rank block carries identical rows
    for key, segs in lay.segments.items():
        for seg in segs:
            if seg.shard_axis is not None:
                continue
            r0 = planes[key][seg.row_start: seg.row_start + seg.rows]
            for r in range(1, tp):
                off = r * lay.rows[key] + seg.row_start
                assert bool(
                    jnp.array_equal(r0, planes[key][off: off + seg.rows])
                ), (key, seg.index)

    # f32-cast stacked path (optimizer-state form: leading node axis)
    stacked = jax.tree.map(
        lambda a: jnp.asarray(
            np.random.default_rng(seed + 1).standard_normal((3,) + a.shape),
            jnp.float32,
        ),
        tree,
    )
    sp = lay.pack_global(stacked, dtype=jnp.float32, leading=1)
    assert _tree_equal(
        lay.unpack_global(sp, dtype=jnp.float32, leading=1), stacked
    )


@pytest.mark.parametrize("tp", (1, 2, 4))
@pytest.mark.parametrize("seed", range(5))
def test_sharded_roundtrip_sweep(seed, tp):
    """Seeded fallback of the hypothesis property — always runs, so the
    invariant is exercised even where the [test] extra is absent."""
    _check_sharded_roundtrip(seed, tp)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        tp=st.sampled_from([1, 2, 4]),
    )
    def test_sharded_roundtrip_property(seed, tp):
        """Hypothesis-driven version of the same contract (wider seed
        space + shrinking on failure)."""
        _check_sharded_roundtrip(seed, tp)


# ---------------------------------------------------------------------------
# sharded parity: all 11 algorithms on per-rank local buckets
# ---------------------------------------------------------------------------


def _sharded_tmpl_specs():
    from jax.sharding import PartitionSpec as P

    tmpl = {
        "win": jnp.zeros((8, 64), jnp.float32),
        "wout": jnp.zeros((64, 8), jnp.float32),
        "emb": jnp.zeros((48, 33), jnp.bfloat16),
        "w2": jnp.zeros((2000,), jnp.bfloat16),
        "ln": jnp.zeros((9,), jnp.float32),
        "b": jnp.zeros((), jnp.float32),
    }
    specs = {
        "win": P(None, "model"),
        "wout": P("model", None),
        "emb": P("model", None),
        "w2": P(None),
        "ln": None,
        "b": P(),
    }
    return tmpl, specs


@pytest.mark.parametrize("tp", (2, 4))
@pytest.mark.parametrize("algo", ALGORITHMS)
def test_plane_parity_sharded_local(algo, tp):
    """One mesh column's view of a sharded layout: the whole-plane Pallas
    stage on the LOCAL buckets is bit-exact with the per-leaf stage on the
    local tree, for all 11 algorithms with LARS row scalars + clip + decay
    and staleness damping — the acceptance anchor's fast-tier half (the
    8-device shard_map half lives in tests/test_distributed.py)."""
    tmpl, specs = _sharded_tmpl_specs()
    lay = PlaneLayout.build(tmpl, tp=tp, shardings=specs)
    local = _rand_like(jax.tree.map(jnp.zeros_like, lay.local_template()))
    cfg = OptimizerConfig(
        algorithm=algo, momentum=0.9, lars=True, weight_decay=0.01,
        grad_clip=1.0,
    )
    spec = update_spec(cfg)
    g = _rand_like(local, jnp.float32)
    state = make_optimizer(cfg).init(local)

    def gossip(tree, step, comp):
        return jax.tree.map(lambda a: 0.7 * a, tree), comp

    ng = jnp.int32(2) if spec.staleness_aware else None
    kw = dict(lr=0.01, step_idx=jnp.int32(3), gossip=gossip, mean=lambda t: t,
              comp_state=(), node_gaps=ng)
    x1, s1, _ = run_update(spec, cfg, x=local, g=g, state=state,
                           stage=make_stage("pallas_interpret"), **kw)
    x2p, s2p, _ = run_update(
        spec, cfg, x=lay.pack(local), g=lay.pack(g, dtype=jnp.float32),
        state={k: lay.pack(v, dtype=jnp.float32) for k, v in state.items()},
        stage=make_plane_stage("pallas_interpret"),
        scalars=plane_scalars(cfg, lay, local, g), **kw,
    )
    assert _tree_equal(x1, lay.unpack(x2p, like=local))
    for sk in s1:
        assert _tree_equal(s1[sk], lay.unpack(s2p[sk], dtype=jnp.float32)), sk


def test_sharded_launch_count_matches_tp1_collapse():
    """Per-rank launch count on a sharded layout equals the tp == 1
    collapse: O(buckets x stages), independent of tp (jaxpr-counted)."""
    tmpl, specs = _sharded_tmpl_specs()
    cfg = OptimizerConfig(algorithm="decentlam", momentum=0.9)
    spec = update_spec(cfg)
    stages = len(stage_plan(cfg))

    def count_for(lay, tree):
        g = _rand_like(tree, jnp.float32)
        state = make_optimizer(cfg).init(tree)
        kw = dict(lr=0.01, step_idx=jnp.int32(0),
                  gossip=lambda t, s, c: (t, c), mean=lambda t: t,
                  comp_state=())

        def plane_fn(x, g, state):
            return run_update(
                spec, cfg, x=lay.pack(x), g=lay.pack(g, dtype=jnp.float32),
                state={k: lay.pack(v, dtype=jnp.float32)
                       for k, v in state.items()},
                stage=make_plane_stage("pallas_interpret"),
                scalars=plane_scalars(cfg, lay, tree, g), **kw,
            )

        return count_primitive(
            jax.make_jaxpr(plane_fn)(tree, g, state), "pallas_call"
        )

    lay1 = PlaneLayout.build(tmpl)
    counts = {1: count_for(lay1, tmpl)}
    for tp in (2, 4):
        lay = PlaneLayout.build(tmpl, tp=tp, shardings=specs)
        local = jax.tree.map(jnp.zeros_like, lay.local_template())
        counts[tp] = count_for(lay, local)
    assert counts[1] == len(lay1.segments) * stages
    assert counts[2] == counts[1] and counts[4] == counts[1]


# ---------------------------------------------------------------------------
# cross-tp checkpoint restore (V3 manifest plane_tp)
# ---------------------------------------------------------------------------


def test_reconcile_plane_state_cross_tp(tmp_path):
    """Optimizer plane state written at tp=2 restores at tp=1 (and back)
    bit-exactly through the global tree, keyed off the V3 manifest's
    ``plane_tp``; layouts whose global templates disagree are rejected."""
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.train_state import (
        model_plane_layout, reconcile_plane_state,
    )

    cfg = _tp_cfg()
    lay1 = model_plane_layout(cfg, 1)
    lay2 = model_plane_layout(cfg, 2)
    n = 3
    m = jax.tree.map(
        lambda a: jnp.asarray(
            RNG.standard_normal((n,) + a.shape), jnp.float32
        ),
        lay1.global_template(),
    )
    packed1 = lay1.pack_global(m, dtype=jnp.float32, leading=1)
    packed2 = lay2.pack_global(m, dtype=jnp.float32, leading=1)

    # tp=2 checkpoint -> tp=1 run
    state = {"step": jnp.int32(5), "params": {}, "opt": {"m": packed2}}
    out = reconcile_plane_state(state, lay1, True, stored_layout=lay2)
    assert _tree_equal(out["opt"]["m"], packed1)
    # tp=1 checkpoint -> tp=2 run
    back = reconcile_plane_state(
        {**state, "opt": {"m": packed1}}, lay2, True, stored_layout=lay1
    )
    assert _tree_equal(back["opt"]["m"], packed2)
    # cross-tp restore straight to tree form (flat_planes turned off)
    tree = reconcile_plane_state(state, lay1, False, stored_layout=lay2)
    assert _tree_equal(tree["opt"]["m"], m)

    # the manifest carries the layout the checkpoint was written with
    save_checkpoint(str(tmp_path), jax.device_get(state), plane_layout=lay2)
    restored, manifest = restore_checkpoint(str(tmp_path))
    assert manifest["plane_tp"] == 2
    assert manifest["plane_rows"] == {k: int(v) for k, v in lay2.rows.items()}
    stored = model_plane_layout(cfg, int(manifest["plane_tp"]))
    out2 = reconcile_plane_state(restored, lay1, True, stored_layout=stored)
    assert _tree_equal(out2["opt"]["m"], packed1)

    # incompatible global templates (different vocab padding) refuse loudly
    import dataclasses

    other = model_plane_layout(
        dataclasses.replace(cfg, vocab_size=cfg.vocab_size + 2), 1
    )
    with pytest.raises(ValueError, match="mismatch|structure"):
        reconcile_plane_state(state, other, True, stored_layout=lay2)


def test_reconcile_tree_form_ignores_cross_tp_padding():
    """A tree-form opt state resumes across tp even when tp-dependent
    padding (vocab_padded) differs between the stored and current layouts
    — the per-leaf production path.  Regression: the global-template
    compatibility check must run lazily, only when a plane-form bucket
    actually needs cross-tp conversion, not eagerly whenever
    ``stored.tp != plane_layout.tp``."""
    import dataclasses

    from repro.train.train_state import (
        model_plane_layout, reconcile_plane_state,
    )

    # vocab 13 does not divide tp=2, so the tp=2 template pads it to 14
    # while tp=1 keeps 13 — the layouts' global templates disagree
    cfg = dataclasses.replace(_tp_cfg(), vocab_size=13)
    lay1 = model_plane_layout(cfg, 1)
    lay2 = model_plane_layout(cfg, 2)
    with pytest.raises(ValueError, match="mismatch|structure"):
        # sanity: these layouts really are plane-inconvertible
        from repro.train.train_state import _check_same_global_template

        _check_same_global_template(lay1, lay2)

    n = 3
    m = jax.tree.map(
        lambda a: jnp.asarray(
            RNG.standard_normal((n,) + a.shape), jnp.float32
        ),
        lay2.global_template(),
    )
    state = {"step": jnp.int32(5), "params": {}, "opt": {"m": m}}
    # tp=1-written manifest resumed at tp=2 per-leaf: passes through intact
    out = reconcile_plane_state(state, lay2, False, stored_layout=lay1)
    assert _tree_equal(out["opt"]["m"], m)
    # and the flat-planes resume of a tree-form state packs with the
    # *current* layout without ever touching the stored one
    packed = reconcile_plane_state(state, lay2, True, stored_layout=lay1)
    assert _tree_equal(packed["opt"]["m"],
                       lay2.pack_global(m, dtype=jnp.float32, leading=1))


def test_check_plane_manifest_detects_config_drift(tmp_path):
    """The resume path cross-checks the manifest's ``plane_rows`` /
    ``plane_model_axis`` against the layout rebuilt from the current
    config, so config drift fails fast instead of deep inside unpack."""
    import dataclasses

    from repro.train.checkpoint import (
        check_plane_manifest, restore_checkpoint, save_checkpoint,
    )
    from repro.train.train_state import model_plane_layout

    cfg = _tp_cfg()
    lay2 = model_plane_layout(cfg, 2)
    m = jax.tree.map(
        lambda a: jnp.asarray(
            RNG.standard_normal((1,) + a.shape), jnp.float32
        ),
        lay2.global_template(),
    )
    state = {
        "step": jnp.int32(5), "params": {},
        "opt": {"m": lay2.pack_global(m, dtype=jnp.float32, leading=1)},
    }
    save_checkpoint(str(tmp_path), jax.device_get(state), plane_layout=lay2)
    _, manifest = restore_checkpoint(str(tmp_path))

    # same config: clean
    check_plane_manifest(manifest, model_plane_layout(cfg, 2))
    # manifests without plane metadata (pre-sharded-layout) pass through
    check_plane_manifest({"format": 3, "step": 5}, lay2)
    # drifted model config: loud, actionable failure
    drifted = model_plane_layout(
        dataclasses.replace(cfg, d_ff=cfg.d_ff * 2), 2
    )
    with pytest.raises(ValueError, match="plane_rows"):
        check_plane_manifest(manifest, drifted)
    with pytest.raises(ValueError, match="plane_model_axis"):
        check_plane_manifest(
            {**manifest, "plane_model_axis": "tensor"}, lay2
        )


def test_ensure_channel_state_plane_template():
    """A plane-layout TrainState resumes its channel bucket when shapes
    match and zero-inits it when the payload layout changed."""
    from repro.train.train_state import ensure_channel_state

    n = 2
    tmpl = {"w": jnp.zeros((300,), jnp.float32), "s": jnp.zeros((5,), jnp.float32)}
    lay = PlaneLayout.build(tmpl)
    topo = build_topology("ring", 4)
    chan = DelayedStackedChannel(topo, 1)
    params = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tmpl
    )
    plane_t = lay.pack(tmpl, dtype=jnp.float32)
    chan_state = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape)
        + jnp.asarray(1, a.dtype),
        chan.init(plane_t),
    )
    state = {"step": jnp.int32(1), "params": params, "opt": {},
             "channel": chan_state}
    out = ensure_channel_state(state, chan, n, lay)
    assert _tree_equal(out["channel"], chan_state)  # matching resume survives
    # a different layout (template grew past the 64-row plane quantum, so
    # the packed buffer shape changes) invalidates the delay buffers
    tmpl2 = {"w": jnp.zeros((70000,), jnp.float32), "s": jnp.zeros((5,), jnp.float32)}
    lay2 = PlaneLayout.build(tmpl2)
    params2 = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tmpl2
    )
    out2 = ensure_channel_state(
        {**state, "params": params2}, chan, n, lay2
    )
    assert all(
        float(jnp.sum(jnp.abs(leaf))) == 0.0
        for leaf in jax.tree.leaves(out2["channel"]["delay"])
    )
