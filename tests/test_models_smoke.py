"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-style grad step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models import transformer as T
from repro.models.layers import TPContext

RT = T.RuntimeConfig(dtype="float32", remat=False)
TP1 = TPContext(size=1)


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model)), jnp.float32
        )
    if cfg.arch_kind == "encdec":
        b["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    return b


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_forward_and_grad_step(arch):
    cfg = SMOKES[arch]
    params = T.init_params(jax.random.key(0), cfg, tp=1)
    batch = _batch(cfg)

    @jax.jit
    def loss_and_grad(p, b):
        def lf(pp):
            return T.forward_loss(pp, b, cfg, TP1, RT)

        (l, m), g = jax.value_and_grad(lf, has_aux=True)(p)
        return l, m, g

    loss, metrics, grads = loss_and_grad(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), arch
    # one SGD step decreases loss on the same batch
    p2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    l2, _, _ = loss_and_grad(p2, batch)
    assert float(l2) < float(loss), (arch, float(loss), float(l2))


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_logit_shapes(arch):
    cfg = SMOKES[arch]
    params = T.init_params(jax.random.key(0), cfg, tp=1)
    batch = _batch(cfg, B=2, S=8)
    logits, cache = jax.jit(
        lambda p, b: T.prefill(p, b, cfg, TP1, RT, target_len=16)
    )(params, batch)
    assert logits.shape == (2, cfg.vocab_padded(1))
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_param_specs_cover_params(arch):
    cfg = SMOKES[arch]
    params = jax.eval_shape(lambda k: T.init_params(k, cfg, tp=2), jax.random.key(0))
    specs = T.param_specs(cfg, tp=2)
    pl = jax.tree_util.tree_structure(params)
    from jax.sharding import PartitionSpec as P

    sl = jax.tree_util.tree_structure(
        jax.tree.map(lambda s: 0, specs, is_leaf=lambda x: isinstance(x, P))
    )
    assert pl == sl, f"{arch}: param tree and spec tree differ"
    # every spec's non-None axes index valid dims of its param
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree.leaves(
        jax.tree.map(lambda s: (s,), specs, is_leaf=lambda x: isinstance(x, P))
    )
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape) + 1


def test_block_groups_partition_layers():
    for arch, cfg in SMOKES.items():
        groups = T.block_groups(cfg)
        layers = [i for g in groups for i in g.layers]
        assert layers == list(range(cfg.n_layers)), arch


def test_hymba_group_structure():
    cfg = SMOKES["hymba-1.5b"]  # global at (0, 3), window elsewhere, 4 layers
    groups = T.block_groups(cfg)
    kinds = [(g.kind, g.window) for g in groups]
    assert kinds == [
        ("hybrid", 0),
        ("hybrid", cfg.sliding_window),
        ("hybrid", 0),
    ]


def test_xlstm_group_structure():
    cfg = SMOKES["xlstm-350m"]  # slstm_every=2, 4 layers -> m,s,m,s
    groups = T.block_groups(cfg)
    assert [g.kind for g in groups] == ["mlstm", "slstm", "mlstm", "slstm"]
