"""Serve spec derivation: the replicated-batch fallback.

``_batch_axes`` only shards the serve batch over the node axes when the
global batch divides the node-axis extent; otherwise (e.g. a single
request on an 8-way mesh) the batch stays **replicated** while the params
keep their model sharding — both prefill and decode specs must degrade
that way.  Execution parity of the fallback path runs in
``tests/scripts/distributed_serve.py`` (prefill-b1 / decode-b1 sections).
"""

import types

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import tiny_lm
from repro.train.serve import _batch_axes, serve_specs

MESH8 = types.SimpleNamespace(shape={"data": 8, "model": 1})
CFG = tiny_lm(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
              vocab_size=64)


def test_batch_axes_divisibility():
    assert _batch_axes(8, ("data",), MESH8) == ("data",)
    assert _batch_axes(16, ("data",), MESH8) == ("data",)
    for gb in (1, 3, 4, 12):  # indivisible or undersized -> replicated
        assert _batch_axes(gb, ("data",), MESH8) is None
    # multi-axis fleets multiply the extents
    mesh = types.SimpleNamespace(shape={"data": 4, "fleet": 2, "model": 1})
    assert _batch_axes(8, ("data", "fleet"), mesh) == ("data", "fleet")
    assert _batch_axes(4, ("data", "fleet"), mesh) is None


def test_serve_specs_replicated_fallback():
    """global_batch=1 on an 8-way node axis: token + cache batch dims drop
    to None (replicated) for both prefill and decode consumers, while the
    param specs are untouched by the batch decision."""
    p8, c8, tok8, ba8 = serve_specs(CFG, MESH8, global_batch=8)
    p1, c1, tok1, ba1 = serve_specs(CFG, MESH8, global_batch=1)
    assert ba8 == ("data",) and ba1 is None
    assert tok8 == P(("data",), None) and tok1 == P(None, None)
    # params: identical specs either way (model sharding only)
    assert jax.tree.map(
        lambda a, b: a == b, p8, p1, is_leaf=lambda x: isinstance(x, P)
    )

    def batch_dim(spec):
        return spec[1]  # cache leaves are (Lg, B, ...)

    for leaf in jax.tree.leaves(c8, is_leaf=lambda x: isinstance(x, P)):
        assert batch_dim(leaf) == ("data",), leaf
    for leaf in jax.tree.leaves(c1, is_leaf=lambda x: isinstance(x, P)):
        assert batch_dim(leaf) is None, leaf
