"""Validate the jaxpr cost model against fully-unrolled XLA cost analysis."""

import jax
import jax.numpy as jnp

from repro.compat import cost_analysis, shard_map
from repro.launch.costmodel import analyze_lowered


def test_scan_flops_match_unrolled_xla():
    d, L = 128, 10
    x = jnp.zeros((d, d))
    w = jnp.zeros((L, d, d))

    def rolled(x, w):
        out, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return out

    def unrolled(x, w):
        out, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w, unroll=L)
        return out

    xla = cost_analysis(jax.jit(unrolled).lower(x, w).compile())["flops"]
    ours = analyze_lowered(rolled, (x, w), {}).flops
    # elementwise accounting adds O(d^2); dot flops are O(L d^3)
    assert abs(ours - xla) / xla < 0.02, (ours, xla)


def test_nested_scan_trip_counts():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x = jnp.zeros((64, 64))
    costs = analyze_lowered(f, (x,), {})
    expect = 15 * 2 * 64**3  # 5*3 matmuls
    assert abs(costs.flops - expect) / expect < 0.05


def test_grad_includes_backward_flops():
    w = jnp.zeros((64, 64))
    x = jnp.zeros((8, 64))

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    fwd = analyze_lowered(loss, (w, x), {}).flops
    both = analyze_lowered(jax.grad(loss), (w, x), {}).flops
    assert both > 1.8 * fwd  # fwd matmul + dw backward matmul


def test_remat_counted_as_recompute():
    w = jnp.zeros((16, 64, 64))
    x = jnp.zeros((8, 64))

    def net(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return jnp.sum(out)

    def net_remat(w, x):
        def body(c, wi):
            return jax.checkpoint(lambda cc, ww: jnp.tanh(cc @ ww))(c, wi), None
        out, _ = jax.lax.scan(body, x, w)
        return jnp.sum(out)

    plain = analyze_lowered(jax.grad(net), (w, x), {}).flops
    remat = analyze_lowered(jax.grad(net_remat), (w, x), {}).flops
    assert remat > plain  # recompute shows up


def test_collective_bytes_with_axis_sizes():
    mesh_axes = {"data": 8}

    def f(x):
        y = jax.lax.psum(x, "data")
        z = jax.lax.ppermute(y, "data", [(i, (i + 1) % 8) for i in range(8)])
        return z

    # trace with an abstract mesh context via shard_map
    mesh = jax.make_mesh((1,), ("data",))  # sizes come from axis_sizes arg

    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    x = jnp.zeros((1024,), jnp.float32)  # 4 KiB
    sm = shard_map(f, mesh=jax.make_mesh((1,), ("data",)),
                   in_specs=P(), out_specs=P(), check_vma=False)
    costs = analyze_lowered(sm, (x,), mesh_axes)
    nbytes = 1024 * 4
    expect = 2 * (7 / 8) * nbytes + nbytes  # all-reduce + permute
    assert abs(costs.collective_bytes - expect) / expect < 1e-6
    assert costs.collective_counts["all-reduce"] == 1
    assert costs.collective_counts["collective-permute"] == 1


def test_collectives_inside_scan_are_multiplied():
    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "data"), None
        out, _ = jax.lax.scan(body, x, None, length=6)
        return out

    from jax.sharding import PartitionSpec as P

    x = jnp.zeros((256,), jnp.float32)
    sm = shard_map(f, mesh=jax.make_mesh((1,), ("data",)),
                   in_specs=P(), out_specs=P(), check_vma=False)
    costs = analyze_lowered(sm, (x,), {"data": 4})
    expect = 6 * 2 * (3 / 4) * 256 * 4
    assert abs(costs.collective_bytes - expect) / expect < 1e-6
