import numpy as np

from repro.core.schedules import (
    ScheduleConfig,
    build_schedule,
    linear_scaled_lr,
    warmup_cosine,
    warmup_step_decay,
)
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig


def test_synthetic_deterministic():
    c = SyntheticLMConfig(vocab_size=64, seq_len=8, per_node_batch=2, n_nodes=4)
    a = SyntheticLM(c).batch(3)
    b = SyntheticLM(c).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["targets"], b["targets"])


def test_targets_are_shifted_tokens():
    c = SyntheticLMConfig(vocab_size=64, seq_len=8, per_node_batch=1, n_nodes=2)
    b = SyntheticLM(c).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_heterogeneity_controls_node_divergence():
    base = dict(vocab_size=256, seq_len=64, per_node_batch=4, n_nodes=4, noise=0.0)
    homog = SyntheticLM(SyntheticLMConfig(**base, heterogeneity=0.0))
    heter = SyntheticLM(SyntheticLMConfig(**base, heterogeneity=1.0))
    assert (homog.a == homog.a[0]).all() and (homog.b == homog.b[0]).all()
    assert len(set(heter.a.tolist())) > 1 or len(set(heter.b.tolist())) > 1


def test_linear_scaling_rule():
    assert linear_scaled_lr(0.1, 2048, 256) == 0.8


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, warmup_steps=10, total_steps=110)
    assert float(f(0)) < float(f(5)) < float(f(9))
    assert abs(float(f(10)) - 1.0) < 0.1
    assert float(f(109)) < 0.01
    mid = float(f(60))
    assert 0.3 < mid < 0.7


def test_warmup_step_decay():
    f = warmup_step_decay(1.0, warmup_steps=5, boundaries=[50, 80], factor=0.1)
    assert abs(float(f(30)) - 1.0) < 1e-6
    assert abs(float(f(60)) - 0.1) < 1e-6
    assert abs(float(f(90)) - 0.01) < 1e-7


def test_build_schedule_dispatch():
    for kind in ("constant", "warmup_cosine", "warmup_step"):
        f = build_schedule(ScheduleConfig(kind=kind, peak_lr=0.5, warmup_steps=2,
                                          total_steps=10))
        v = float(f(5))
        assert 0.0 < v <= 0.5
