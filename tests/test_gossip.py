"""Gossip channel + compression unit tests (stacked harness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AllgatherChannel,
    DelayedPpermuteChannel,
    DelayedStackedChannel,
    PpermuteChannel,
    StackedChannel,
    build_channel,
    build_topology,
    consensus_distance,
    get_compressor,
    gossip_bytes_per_step,
    wire_bytes,
)


def test_gossip_preserves_mean():
    topo = build_topology("exp", 8)
    ch = StackedChannel(topo)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 17)), jnp.float32)
    _, y = ch.apply(ch.init(x), x, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y, 0)), np.asarray(jnp.mean(x, 0)), atol=1e-5
    )


@pytest.mark.parametrize("name", ["ring", "torus", "exp"])
def test_gossip_contracts_consensus_by_rho(name):
    topo = build_topology(name, 16)
    ch = StackedChannel(topo)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 33)), jnp.float32)
    _, y = ch.apply({}, x, jnp.int32(0))
    c0 = float(consensus_distance(x))
    c1 = float(consensus_distance(y))
    assert c1 <= topo.rho() ** 2 * c0 * (1 + 1e-4), (name, c1 / c0, topo.rho() ** 2)


def test_repeated_gossip_converges_to_mean():
    topo = build_topology("one-peer-exp", 8)
    ch = StackedChannel(topo)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 5)), jnp.float32)
    target = jnp.mean(x, axis=0)
    y = x
    for k in range(64):
        _, y = ch.apply({}, y, jnp.int32(k))
    np.testing.assert_allclose(
        np.asarray(y), np.broadcast_to(np.asarray(target), y.shape), atol=1e-4
    )


def test_int8_compressor_roundtrip_error():
    c = get_compressor("int8")
    x = jnp.asarray(np.random.default_rng(3).standard_normal(1000), jnp.float32)
    msg, _ = c.encode(x, ())
    y = c.decode(msg, x)
    err = float(jnp.max(jnp.abs(x - y)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_topk_error_feedback_accumulates():
    c = get_compressor("topk:0.1")
    x = jnp.asarray(np.random.default_rng(4).standard_normal(100), jnp.float32)
    err = c.init(x)
    # repeated transmission of the same payload: error feedback ensures the
    # cumulative decoded mass approaches the payload
    decoded_sum = jnp.zeros_like(x)
    for _ in range(30):
        msg, err = c.encode(x, err)
        decoded_sum = decoded_sum + c.decode(msg, x)
    avg = decoded_sum / 30.0
    assert float(jnp.linalg.norm(avg - x)) / float(jnp.linalg.norm(x)) < 0.2


def test_comm_volume_model_favors_sparse_topologies():
    payload = 100e6  # 100 MB of params
    ring = gossip_bytes_per_step(build_topology("ring", 64), payload)
    onep = gossip_bytes_per_step(build_topology("one-peer-exp", 64), payload)
    allg = gossip_bytes_per_step(
        build_topology("ring", 64), payload, impl="allgather"
    )
    # degree-bounded gossip is O(1) in n; all-gather is O(n)
    assert onep["egress_bytes"] < ring["egress_bytes"] < allg["egress_bytes"]
    assert allg["egress_bytes"] > 50 * onep["egress_bytes"]


def test_wire_bytes_model():
    assert wire_bytes(1000, None) == 1000
    assert wire_bytes(1000, "bf16") == 500
    assert wire_bytes(1000, "int8") == pytest.approx(254)
    assert wire_bytes(4000, "topk:0.01") == pytest.approx(0.01 * 1000 * 8)


# ---------------------------------------------------------------------------
# gossip_bytes_per_step: the Fig. 6 comm-volume model, impl x compression
# ---------------------------------------------------------------------------

N = 8
PAYLOAD = 4.0 * 1_000_000  # 1M fp32 params on the wire
COMPRESSIONS = [None, "bf16", "int8", "topk:0.05"]
# sends per step for n=8, averaged over the topology period
DEGREES = {"ring": 2.0, "exp": 6.0, "one-peer-exp": 1.0, "torus": 3.0}


@pytest.mark.parametrize("comp", COMPRESSIONS)
def test_wire_bytes_matches_encoded_message(comp):
    """The analytic model must equal the actual encoded bytes on the wire."""
    n = 4000
    x = jnp.asarray(np.random.default_rng(5).standard_normal(n), jnp.float32)
    c = get_compressor(comp)
    msg, _ = c.encode(x, c.init(x))
    actual = sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(msg))
    assert actual == pytest.approx(wire_bytes(x.nbytes, comp), rel=1e-6)


@pytest.mark.parametrize("comp", COMPRESSIONS)
@pytest.mark.parametrize("name", sorted(DEGREES))
def test_gossip_bytes_ppermute_scales_with_degree_and_compression(name, comp):
    topo = build_topology(name, N)
    out = gossip_bytes_per_step(topo, PAYLOAD, impl="ppermute", compression=comp)
    assert out["hops"] == DEGREES[name]
    assert out["egress_bytes"] == pytest.approx(
        DEGREES[name] * wire_bytes(PAYLOAD, comp)
    )


def test_gossip_bytes_allgather_uncompressed():
    """The naive baseline ships raw fp32: O(n) egress regardless of topology
    (GSPMD all-gathers the payload before the local W-row reduction)."""
    topo = build_topology("ring", N)
    out = gossip_bytes_per_step(topo, PAYLOAD, impl="allgather", compression=None)
    assert out["egress_bytes"] == pytest.approx((N - 1) * PAYLOAD)
    assert out["hops"] == N - 1


@pytest.mark.parametrize("comp", [c for c in COMPRESSIONS if c is not None])
def test_gossip_bytes_allgather_rejects_compression(comp):
    """Compression cannot help the all-gather path, so asking for it is an
    explicit error instead of silently pricing raw bytes."""
    topo = build_topology("ring", N)
    with pytest.raises(ValueError, match="cannot compress"):
        gossip_bytes_per_step(topo, PAYLOAD, impl="allgather", compression=comp)


def test_gossip_bytes_compression_ordering():
    """For any fixed topology: topk:0.05 < int8 < bf16 < none egress."""
    topo = build_topology("exp", N)

    def egress(comp):
        return gossip_bytes_per_step(topo, PAYLOAD, compression=comp)[
            "egress_bytes"
        ]

    assert egress("topk:0.05") < egress("int8") < egress("bf16") < egress(None)


# ---------------------------------------------------------------------------
# GossipChannel protocol
# ---------------------------------------------------------------------------


def _x(n=8, d=7, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32
    )


def test_legacy_closure_protocol_still_accepted_but_factories_removed():
    """The deprecated factory shims are gone (one-release grace period
    over); ad-hoc closures with the legacy signature still work as the
    ``gossip`` callback (test oracles rely on this)."""
    import repro.core as core
    import repro.core.gossip as gossip_mod

    for name in ("make_stacked_gossip", "make_ppermute_gossip",
                 "make_allgather_gossip", "init_compression_state"):
        assert not hasattr(core, name), name
        assert not hasattr(gossip_mod, name), name
    from repro.sim import delayed_gossip

    for name in ("make_delayed_stacked_gossip", "init_delay_state"):
        assert not hasattr(delayed_gossip, name), name


def test_stacked_channel_compression_matches_manual_model():
    """Compressed stacked mix == diag(W) x + W_off @ decode(encode(x))."""
    topo = build_topology("ring", 8)
    ch = StackedChannel(topo, compression="int8")
    c = get_compressor("int8")
    x = _x(seed=3)
    _, y = ch.apply(ch.init(x), x, jnp.int32(0))
    W = topo.W(0)
    xhat = np.stack(
        [np.asarray(c.decode(c.encode(x[i], ())[0], x[i])) for i in range(8)]
    )
    exp = np.diag(W)[:, None] * np.asarray(x) + (
        W - np.diag(np.diag(W))
    ) @ xhat
    np.testing.assert_allclose(np.asarray(y), exp.astype(np.float32), atol=1e-5)


def test_stacked_channel_topk_error_feedback_state():
    topo = build_topology("ring", 8)
    ch = StackedChannel(topo, compression="topk:0.2")
    x = _x(seed=4)
    st = ch.init(x)
    assert jax.tree.leaves(st["comp"])[0].shape == x.shape
    st, _ = ch.apply(st, x, jnp.int32(0))
    assert float(np.abs(np.asarray(jax.tree.leaves(st["comp"])[0])).sum()) > 0


def test_delayed_channel_delay0_bit_exact_and_gapless():
    topo = build_topology("torus", 8)
    plain, delayed = StackedChannel(topo), DelayedStackedChannel(topo, 0)
    x = _x(seed=5)
    _, y0 = plain.apply({}, x, jnp.int32(0))
    st = delayed.init(x)
    st, y1 = delayed.apply(st, x, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert int(np.max(np.asarray(delayed.version_gaps(st)))) == 0


def test_delayed_channel_version_gaps_warmup_and_cap():
    """Gaps report the staleness the most recent round actually used —
    min(d, round) under the warmup rule — stay within the configured delay,
    and are zero off the gossip support."""
    topo = build_topology("ring", 8)
    ch = DelayedStackedChannel(topo, 3)
    x = _x(seed=6)
    st = ch.init(x)
    W_off = topo.W(0) - np.diag(np.diag(topo.W(0)))
    assert np.asarray(ch.version_gaps(st)).max() == 0  # nothing mixed yet
    for t in range(5):
        st, _ = ch.apply(st, x, jnp.int32(t))
        gaps = np.asarray(ch.version_gaps(st))
        # round t read hist[count - min(d, t)] — exactly min(3, t) rounds old
        assert gaps.max() == min(3, t)
        assert (gaps[W_off == 0] == 0).all()


def test_node_gaps_incident_edge_semantics():
    """node_gaps is the worst version gap on any *incident* edge, both
    directions: with an asymmetric delay matrix, a node whose own reads are
    fresh but whose readers consume it stale still reports the gap (the
    momentum feedback staleness-aware algorithms damp runs through the
    round trip).  Staleness-free channels report scalar 0."""
    topo = build_topology("ring", 4)
    Dm = np.zeros((4, 4), int)
    Dm[1, 0] = 3  # node 1 reads node 0's payload 3 rounds stale
    ch = DelayedStackedChannel(topo, Dm)
    x = _x(4, 5)
    st = ch.init(x)
    for t in range(5):
        st, _ = ch.apply(st, x, jnp.int32(t))
    gaps = np.asarray(ch.node_gaps(st))
    assert gaps.shape == (4,)
    assert gaps[1] == 3  # stale reader
    assert gaps[0] == 3  # fresh reads, but its payloads are consumed stale
    assert gaps[2] == 0 and gaps[3] == 0
    # staleness-free transports: scalar 0 (broadcastable in any layout)
    st0 = StackedChannel(topo).init(x)
    assert np.asarray(StackedChannel(topo).node_gaps(st0)).shape == ()
    assert int(StackedChannel(topo).node_gaps(st0)) == 0


def test_channel_telemetry_accounting():
    """rounds/bytes telemetry integrates bytes_per_step over applies."""
    topo = build_topology("exp", 8)
    ch = StackedChannel(topo, telemetry=True)
    x = _x()
    per_node_payload = 4.0 * x.size / 8
    st = ch.init(x)
    for t in range(3):
        st, _ = ch.apply(st, x, jnp.int32(t))
    assert int(st["t"]["rounds"]) == 3
    expected = 3 * ch.bytes_per_step(per_node_payload)["egress_bytes"]
    assert float(st["t"]["bytes"]) == pytest.approx(expected)


def test_channel_bytes_per_step_matches_analytic_model():
    """Cross-check against an independent re-derivation (mean edge-class
    sends x wire bytes) — NOT against gossip_bytes_per_step, which the
    channel delegates to (that comparison would be vacuous)."""
    for comp in COMPRESSIONS:
        topo = build_topology("exp", 8)
        ch = PpermuteChannel(topo, ("data",), compression=comp)
        got = ch.bytes_per_step(PAYLOAD)
        sends = np.mean(
            [len(topo.edge_classes(t)) for t in range(topo.period)]
        )
        assert got["hops"] == pytest.approx(float(sends))
        assert got["egress_bytes"] == pytest.approx(
            float(sends) * wire_bytes(PAYLOAD, comp)
        )


def test_build_channel_dispatch():
    topo = build_topology("ring", 8)
    assert isinstance(build_channel("stacked", topo), StackedChannel)
    assert isinstance(
        build_channel("stacked", topo, delay=1), DelayedStackedChannel
    )
    assert isinstance(
        build_channel("ppermute", topo, ("data",)), PpermuteChannel
    )
    assert isinstance(
        build_channel("ppermute", topo, ("data",), delay=2),
        DelayedPpermuteChannel,
    )
    assert isinstance(
        build_channel("allgather", topo, ("data",)), AllgatherChannel
    )
    with pytest.raises(ValueError, match="delayed"):
        build_channel("allgather", topo, ("data",), delay=1)
    with pytest.raises(ValueError, match="cannot compress"):
        build_channel("allgather", topo, ("data",), compression="bf16")
    with pytest.raises(ValueError, match="compression"):
        build_channel("ppermute", topo, ("data",), delay=1, compression="int8")
    with pytest.raises(ValueError, match="needs node_axes"):
        build_channel("ppermute", topo)
    with pytest.raises(ValueError, match="unknown gossip impl"):
        build_channel("smoke-signal", topo, ("data",))


def test_channel_state_is_checkpoint_shaped():
    """Channel state is a dict pytree of real arrays — no tuples/empties
    that the npz checkpoint flattening would drop or re-type."""
    topo = build_topology("ring", 4)
    ch = DelayedStackedChannel(
        topo, 2, calls_per_step=2, compression="topk:0.3", telemetry=True
    )
    st = ch.init(_x(4, 5))
    assert set(st) == {"t", "comp", "delay"}
    assert set(st["delay"]) == {"s0", "s1"}
    leaves, treedef = jax.tree.flatten(st)
    assert all(hasattr(l, "shape") for l in leaves)
    # round-trip through numpy (what save/restore does) keeps the structure
    rebuilt = jax.tree.unflatten(treedef, [jnp.asarray(np.asarray(l)) for l in leaves])
    assert jax.tree.structure(rebuilt) == treedef
