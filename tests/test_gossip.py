"""Gossip executor + compression unit tests (stacked harness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_topology,
    consensus_distance,
    get_compressor,
    gossip_bytes_per_step,
    make_stacked_gossip,
    wire_bytes,
)


def test_gossip_preserves_mean():
    topo = build_topology("exp", 8)
    g = make_stacked_gossip(topo)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 17)), jnp.float32)
    y, _ = g(x, jnp.int32(0), ())
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y, 0)), np.asarray(jnp.mean(x, 0)), atol=1e-5
    )


@pytest.mark.parametrize("name", ["ring", "torus", "exp"])
def test_gossip_contracts_consensus_by_rho(name):
    topo = build_topology(name, 16)
    g = make_stacked_gossip(topo)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 33)), jnp.float32)
    y, _ = g(x, jnp.int32(0), ())
    c0 = float(consensus_distance(x))
    c1 = float(consensus_distance(y))
    assert c1 <= topo.rho() ** 2 * c0 * (1 + 1e-4), (name, c1 / c0, topo.rho() ** 2)


def test_repeated_gossip_converges_to_mean():
    topo = build_topology("one-peer-exp", 8)
    g = make_stacked_gossip(topo)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 5)), jnp.float32)
    target = jnp.mean(x, axis=0)
    y = x
    for k in range(64):
        y, _ = g(y, jnp.int32(k), ())
    np.testing.assert_allclose(
        np.asarray(y), np.broadcast_to(np.asarray(target), y.shape), atol=1e-4
    )


def test_int8_compressor_roundtrip_error():
    c = get_compressor("int8")
    x = jnp.asarray(np.random.default_rng(3).standard_normal(1000), jnp.float32)
    msg, _ = c.encode(x, ())
    y = c.decode(msg, x)
    err = float(jnp.max(jnp.abs(x - y)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_topk_error_feedback_accumulates():
    c = get_compressor("topk:0.1")
    x = jnp.asarray(np.random.default_rng(4).standard_normal(100), jnp.float32)
    err = c.init(x)
    # repeated transmission of the same payload: error feedback ensures the
    # cumulative decoded mass approaches the payload
    decoded_sum = jnp.zeros_like(x)
    for _ in range(30):
        msg, err = c.encode(x, err)
        decoded_sum = decoded_sum + c.decode(msg, x)
    avg = decoded_sum / 30.0
    assert float(jnp.linalg.norm(avg - x)) / float(jnp.linalg.norm(x)) < 0.2


def test_comm_volume_model_favors_sparse_topologies():
    payload = 100e6  # 100 MB of params
    ring = gossip_bytes_per_step(build_topology("ring", 64), payload)
    onep = gossip_bytes_per_step(build_topology("one-peer-exp", 64), payload)
    allg = gossip_bytes_per_step(
        build_topology("ring", 64), payload, impl="allgather"
    )
    # degree-bounded gossip is O(1) in n; all-gather is O(n)
    assert onep["egress_bytes"] < ring["egress_bytes"] < allg["egress_bytes"]
    assert allg["egress_bytes"] > 50 * onep["egress_bytes"]


def test_wire_bytes_model():
    assert wire_bytes(1000, None) == 1000
    assert wire_bytes(1000, "bf16") == 500
    assert wire_bytes(1000, "int8") == pytest.approx(254)
    assert wire_bytes(4000, "topk:0.01") == pytest.approx(0.01 * 1000 * 8)


# ---------------------------------------------------------------------------
# gossip_bytes_per_step: the Fig. 6 comm-volume model, impl x compression
# ---------------------------------------------------------------------------

N = 8
PAYLOAD = 4.0 * 1_000_000  # 1M fp32 params on the wire
COMPRESSIONS = [None, "bf16", "int8", "topk:0.05"]
# sends per step for n=8, averaged over the topology period
DEGREES = {"ring": 2.0, "exp": 6.0, "one-peer-exp": 1.0, "torus": 3.0}


@pytest.mark.parametrize("comp", COMPRESSIONS)
def test_wire_bytes_matches_encoded_message(comp):
    """The analytic model must equal the actual encoded bytes on the wire."""
    n = 4000
    x = jnp.asarray(np.random.default_rng(5).standard_normal(n), jnp.float32)
    c = get_compressor(comp)
    msg, _ = c.encode(x, c.init(x))
    actual = sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(msg))
    assert actual == pytest.approx(wire_bytes(x.nbytes, comp), rel=1e-6)


@pytest.mark.parametrize("comp", COMPRESSIONS)
@pytest.mark.parametrize("name", sorted(DEGREES))
def test_gossip_bytes_ppermute_scales_with_degree_and_compression(name, comp):
    topo = build_topology(name, N)
    out = gossip_bytes_per_step(topo, PAYLOAD, impl="ppermute", compression=comp)
    assert out["hops"] == DEGREES[name]
    assert out["egress_bytes"] == pytest.approx(
        DEGREES[name] * wire_bytes(PAYLOAD, comp)
    )


def test_gossip_bytes_allgather_uncompressed():
    """The naive baseline ships raw fp32: O(n) egress regardless of topology
    (GSPMD all-gathers the payload before the local W-row reduction)."""
    topo = build_topology("ring", N)
    out = gossip_bytes_per_step(topo, PAYLOAD, impl="allgather", compression=None)
    assert out["egress_bytes"] == pytest.approx((N - 1) * PAYLOAD)
    assert out["hops"] == N - 1


@pytest.mark.parametrize("comp", [c for c in COMPRESSIONS if c is not None])
def test_gossip_bytes_allgather_rejects_compression(comp):
    """Compression cannot help the all-gather path, so asking for it is an
    explicit error instead of silently pricing raw bytes."""
    topo = build_topology("ring", N)
    with pytest.raises(ValueError, match="cannot compress"):
        gossip_bytes_per_step(topo, PAYLOAD, impl="allgather", compression=comp)


def test_gossip_bytes_compression_ordering():
    """For any fixed topology: topk:0.05 < int8 < bf16 < none egress."""
    topo = build_topology("exp", N)

    def egress(comp):
        return gossip_bytes_per_step(topo, PAYLOAD, compression=comp)[
            "egress_bytes"
        ]

    assert egress("topk:0.05") < egress("int8") < egress("bf16") < egress(None)
