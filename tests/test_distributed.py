"""Multi-device integration tests via subprocess (the main pytest process
keeps 1 CPU device; these workers get 8 simulated devices)."""

import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.distributed, pytest.mark.slow]

SCRIPTS = os.path.join(os.path.dirname(__file__), "scripts")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, *args, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, script), *args],
        capture_output=True,
        text=True,
        timeout=900,
        env=env,
    )
    assert proc.returncode == 0, f"\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.parametrize(
    "mode", ["baseline", "allgather", "compressed", "one-peer", "fused", "topk"]
)
def test_distributed_train_equivalence(mode):
    out = _run("distributed_equivalence.py", mode)
    assert "OK" in out


@pytest.mark.parametrize("mode", ["planes", "planes-delayed", "planes-tp"])
def test_flat_planes_shard_map_parity_and_collective_count(mode):
    """The flat-plane step's trajectory is bit-exact with the per-leaf step
    on a real 8-device mesh, and its lowered jaxpr carries exactly
    O(dtype-buckets x edge-classes) ppermutes where the per-leaf step
    carries O(leaves x edge-classes) — the tentpole's collective-count
    claim, measured on the actual program.  "planes-tp" reruns the claim on
    a 4-node x 2-way-TP mesh with the *sharded* layout (decentlam +
    delay-2 decentlam-sa): per-rank local buckets, ppermute count equal to
    the tp=1 collapse."""
    out = _run("distributed_equivalence.py", mode)
    assert "OK bit-exact" in out


def test_sparse_gossip_train_step_end_to_end():
    """Row-sparse gossip on the production train step (granite-moe SMOKE,
    flat planes): forced dense-fallback is bit-exact with the dense channel
    end-to-end, and tracked sparsity ships measurably fewer bytes."""
    out = _run("distributed_equivalence.py", "sparse")
    assert "sparse: OK bit-exact under forced fallback" in out


def test_sparse_mesh_channels_match_dense_parents():
    """Channel-level mesh pins: all 11 algorithms all-dirty == dense parents
    (exact + delta + int8 + delayed), partial masks match the stacked sparse
    reference with clean rows bit-frozen, collective accounting."""
    out = _run("sparse_distributed.py")
    from repro.core.optimizers import ALGORITHMS

    assert out.count("A ") == len(ALGORITHMS) + 3  # + drift line, int8, delayed
    assert "B exact: OK" in out and "B delta: OK" in out
    assert "B exact-delay2: OK" in out
    assert "C collectives: OK" in out
    assert "sparse-distributed: OK" in out


def test_delayed_ppermute_channel():
    """The redesign's headline capability: a stale_gossip_k2 scenario through
    the shard_map DelayedPpermuteChannel matches the simulator's SSP
    trajectory (DSGD + DmSGD + staleness-aware DecentLaM), and delay-0
    channels are bit-exact with the pre-redesign ppermute gossip for all 11
    algorithms."""
    out = _run("distributed_delayed.py")
    assert "A dsgd: OK" in out and "A dmsgd: OK" in out
    assert "A decentlam-sa: OK" in out
    from repro.core.optimizers import ALGORITHMS

    assert out.count("(bit-exact)") == len(ALGORITHMS)
    # part C: consensus gate off the live mesh channel's fleet_node_gaps —
    # only the warmup rounds (gap <= threshold) ship, nothing after
    assert "C gate: OK (published 2/6 warmup rounds only)" in out
    assert f"delayed-ppermute: OK ({3 + len(ALGORITHMS)} cases)" in out


def test_resilience_fault_tolerant_runtime():
    """The fault-tolerant gossip runtime on a live mesh: (A) a mesh that
    loses nodes 0-1 and rescales per plan_recovery tracks the simulator's
    failstop_quarter trajectory, (B) ResilientChannel(ChaosChannel(ch,
    empty-schedule)) is bit-exact with the bare channel for all 11
    algorithms, (C) a seeded drop + NaN-inject + churn soak stays finite,
    quarantines the poison, declares/resurrects the silent peer through
    the HealthMonitor, rejoins it checkpoint-free from a WeightPublisher
    snapshot, and converges with bounded bias."""
    out = _run("resilience_distributed.py")
    assert "A dsgd: OK" in out and "A dmsgd: OK" in out
    assert "A decentlam-sa: OK" in out
    from repro.core.optimizers import ALGORITHMS

    assert out.count("(bit-exact)") == len(ALGORITHMS)
    assert "C soak: OK" in out
    assert f"resilience-distributed: OK ({3 + len(ALGORITHMS) + 1} cases)" in out


def test_distributed_serve_matches_oracle():
    out = _run("distributed_serve.py")
    assert out.count("OK") == 4


def test_dryrun_cell_end_to_end():
    """One real multi-pod dry-run cell (512 simulated devices) — guards the
    lower+compile+roofline pipeline of deliverable (e)."""
    out = _run("dryrun_smoke.py", devices=512)
    assert out.count("OK") == 3


def test_train_driver_checkpoint_resume():
    """The CLI driver end-to-end: train 8 steps, checkpoint, resume to 16."""
    out = _run("driver_resume.py", devices=4)
    assert "driver resume OK" in out
