"""Discrete-event cluster simulator (repro.sim).

The acceptance contract of the subsystem:

* zero-delay, homogeneous-speed, no-event simulation is **bit-exact** with
  ``run_stacked`` for every algorithm x topology (the oracle remains the
  oracle) — both for the event engine and the delayed-gossip engine;
* scenarios are deterministic from a seed;
* staleness is version-capped and SSP-bounded;
* fail-stop recovery routes through ``plan_recovery`` (reroute and rescale).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALGORITHMS,
    DelayedStackedChannel,
    OptimizerConfig,
    bias_to_optimum,
    build_topology,
    make_linear_regression,
    make_optimizer,
    run_stacked,
)
from repro.sim import (
    ConstantDuration,
    EventQueue,
    FailStop,
    LognormalDuration,
    PeriodicStragglerDuration,
    Scenario,
    SimSpec,
    delay_matrix,
    effective_batch_fraction,
    get_scenario,
    node_rngs,
    project_wallclock,
    run_delayed,
    simulate,
)

N, D, M = 4, 4, 6
TOPOLOGIES = ["ring", "torus", "exp", "one-peer-exp", "random-match", "full"]
# every scenario the discrete-event loop executes (the delayed-engine
# stale_gossip_k* entries run synchronous rounds and have no event loop)
EVENT_SCENARIOS = [
    "homogeneous", "straggler_1slow", "straggler_1slow_async",
    "failstop_quarter", "churn", "straggler_tail",
]


@pytest.fixture(scope="module")
def problem():
    return make_linear_regression(n=N, m=M, d=D, noise=0.01, seed=0, heterogeneity=1.0)


@pytest.fixture(scope="module")
def problem8():
    return make_linear_regression(n=8, m=10, d=6, noise=0.01, seed=1, heterogeneity=1.0)


def _grad(problem):
    return lambda x, _s: problem.grad(x)


def _tree_equal(a, b) -> bool:
    return all(
        bool(jnp.all(x == y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b), strict=True)
    )


def _sim(opt, topology, n, x0, grad_fn, **kw):
    """simulate() through the SimSpec front door (the supported API)."""
    return simulate(opt, SimSpec(topology=topology, n=n, **kw), x0, grad_fn)


# ---------------------------------------------------------------------------
# The oracle remains the oracle (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_event_engine_matches_oracle(problem, algorithm, topology):
    """Homogeneous speeds, no events, zero delay == run_stacked bit-exactly."""
    opt = make_optimizer(OptimizerConfig(algorithm=algorithm, momentum=0.8))
    x0 = jnp.zeros((N, D), jnp.float32)
    p_ref, s_ref, _ = run_stacked(
        opt, build_topology(topology, N), x0, _grad(problem), lr=1e-2, n_steps=4
    )
    res = _sim(
        opt, topology, N, x0, _grad(problem), lr=1e-2, n_steps=4,
        scenario="homogeneous",
    )
    assert (res.steps == 4).all()
    assert _tree_equal(res.params, p_ref)
    assert _tree_equal(res.opt_state, s_ref)


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_delayed_engine_zero_delay_matches_oracle(problem, algorithm):
    opt = make_optimizer(OptimizerConfig(algorithm=algorithm, momentum=0.8))
    x0 = jnp.zeros((N, D), jnp.float32)
    topo = build_topology("ring", N)
    p_ref, s_ref, _ = run_stacked(opt, topo, x0, _grad(problem), lr=1e-2, n_steps=4)
    p, s, _ = run_delayed(
        opt, topo, x0, _grad(problem), delay=0, lr=1e-2, n_steps=4
    )
    assert _tree_equal(p, p_ref)
    assert _tree_equal(s, s_ref)


# ---------------------------------------------------------------------------
# Delayed gossip semantics
# ---------------------------------------------------------------------------


def test_delay_matrix_normalization():
    Dm = delay_matrix(3, 2)
    assert Dm.shape == (3, 3) and (np.diag(Dm) == 0).all() and Dm[0, 1] == 2
    with pytest.raises(AssertionError):
        delay_matrix(3, -1)


@pytest.mark.parametrize("delay", [1, 2, "per-edge"])
def test_delayed_gossip_matches_manual_model(delay):
    """mixed_t == sum_d W_d @ P_{t - min(d, t)} for distinct payloads P_t."""
    n, d = 4, 3
    topo = build_topology("ring", n)
    W = topo.W(0)
    if delay == "per-edge":
        Dm = np.zeros((n, n), int)
        Dm[0, 1] = Dm[1, 0] = 3
        Dm[2, 3] = Dm[3, 2] = 1
    else:
        Dm = delay_matrix(n, delay)
    ch = DelayedStackedChannel(topo, Dm)
    st = ch.init(jnp.zeros((n, d), jnp.float32))
    P = [
        np.float32(np.random.default_rng(t).standard_normal((n, d)))
        for t in range(6)
    ]
    for t in range(6):
        st, mixed = ch.apply(st, jnp.asarray(P[t]), jnp.int32(t))
        expected = np.zeros((n, d), np.float32)
        for dd in np.unique(Dm):
            Wd = np.where(Dm == dd, W, 0.0)
            expected += (Wd @ P[t - min(int(dd), t)]).astype(np.float32)
        np.testing.assert_allclose(np.asarray(mixed), expected, atol=1e-5)


def test_delayed_gossip_slot_rotation_keeps_histories_independent():
    """Two gossip calls per step (da-dmsgd style) must not share buffers."""
    n, d, k = 4, 3, 1
    topo = build_topology("ring", n)
    W = topo.W(0)
    Dm = delay_matrix(n, k)
    ch = DelayedStackedChannel(topo, k, calls_per_step=2)
    st = ch.init(jnp.zeros((n, d), jnp.float32))
    rng = np.random.default_rng(0)
    A = [np.float32(rng.standard_normal((n, d))) for _ in range(4)]
    B = [np.float32(rng.standard_normal((n, d))) for _ in range(4)]
    W0 = np.where(Dm == 0, W, 0.0)
    W1 = np.where(Dm == 1, W, 0.0)
    for t in range(4):
        st, mixed_a = ch.apply(st, jnp.asarray(A[t]), jnp.int32(t))
        st, mixed_b = ch.apply(st, jnp.asarray(B[t]), jnp.int32(t))
        exp_a = W0 @ A[t] + W1 @ A[max(t - 1, 0)]
        exp_b = W0 @ B[t] + W1 @ B[max(t - 1, 0)]
        np.testing.assert_allclose(np.asarray(mixed_a), exp_a.astype(np.float32), atol=1e-5)
        np.testing.assert_allclose(np.asarray(mixed_b), exp_b.astype(np.float32), atol=1e-5)


def test_delayed_gossip_time_varying_topology(problem):
    """One-peer-exp cycles phases under lax.switch with history threading."""
    opt = make_optimizer(OptimizerConfig(algorithm="dmsgd", momentum=0.8))
    x0 = jnp.zeros((N, D), jnp.float32)
    topo = build_topology("one-peer-exp", N)
    p, _, _ = run_delayed(opt, topo, x0, _grad(problem), delay=2, lr=1e-2, n_steps=6)
    assert bool(jnp.all(jnp.isfinite(p)))


def test_delayed_engine_reports_version_gaps(problem):
    """The delayed engine's trace exposes the per-edge version gap — capped
    at the scenario's configured gossip delay."""
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((N, D), jnp.float32)
    r = _sim(
        opt, "ring", N, x0, _grad(problem), lr=1e-2, n_steps=6,
        scenario="stale_gossip_k2", record_dt=2.0,
    )
    gaps = [e["max_gap"] for e in r.trace]
    assert gaps[-1] == 2
    assert gaps[0] == 0  # round 0 mixes fresh payloads (warmup rule)
    assert all(0 <= g <= 2 for g in gaps)


# ---------------------------------------------------------------------------
# Clocks + queue
# ---------------------------------------------------------------------------


def test_event_queue_fifo_on_ties():
    q = EventQueue()
    q.push(1.0, 3)
    q.push(1.0, 1, tag=7)
    q.push(0.5, 2)
    assert [q.pop() for _ in range(3)] == [(0.5, 2, 0), (1.0, 3, 0), (1.0, 1, 7)]


def test_duration_models():
    rng = np.random.default_rng(0)
    assert ConstantDuration(2.0)(0, 0, rng) == 2.0
    model = PeriodicStragglerDuration(base=1.0, factor=3.0, period=4)
    pattern = [model(0, s, rng) for s in range(8)]
    assert pattern == [3.0, 1.0, 1.0, 1.0, 3.0, 1.0, 1.0, 1.0]
    # lognormal: deterministic per seeded stream, mean approx `mean`
    draws1 = [LognormalDuration(2.0, 0.3)(0, s, np.random.default_rng([7, 0])) for s in range(200)]
    draws2 = [LognormalDuration(2.0, 0.3)(0, s, np.random.default_rng([7, 0])) for s in range(200)]
    assert draws1 == draws2
    assert abs(np.mean(draws1) - 2.0) < 0.2


def test_node_rngs_independent_streams():
    a, b = node_rngs(0, 2)
    assert a.standard_normal() != b.standard_normal()
    a2, _ = node_rngs(0, 2)
    assert a2.standard_normal() == node_rngs(0, 2)[0].standard_normal()


# ---------------------------------------------------------------------------
# Scenarios: determinism, staleness bound, BSP quality
# ---------------------------------------------------------------------------


def test_straggler_deterministic_from_seed(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    kw = dict(lr=1e-2, n_steps=20, scenario="straggler_1slow", seed=5)
    r1 = _sim(opt, "ring", 8, x0, _grad(problem8), **kw)
    r2 = _sim(opt, "ring", 8, x0, _grad(problem8), **kw)
    assert (r1.steps == r2.steps).all()
    assert r1.sim_time == r2.sim_time
    assert _tree_equal(r1.params, r2.params)
    r3 = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=20,
                  scenario="straggler_1slow", seed=6)
    assert r3.sim_time != r1.sim_time  # different draws actually happened


def test_straggler_ssp_neighbor_gap_bounded(problem8):
    scenario = get_scenario("straggler_1slow_async", 8, 30)
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=30,
                 scenario=scenario, seed=0)
    topo = build_topology("ring", 8)
    W = topo.W(0)
    for i in range(8):
        for j in np.nonzero(W[i])[0]:
            assert abs(int(r.steps[i]) - int(r.steps[j])) <= scenario.max_staleness
    # the straggler forces everyone else to stall under the SSP bound
    assert r.stall_time.sum() > 0
    assert r.steps.min() >= 30


def test_straggler_bsp_preserves_quality(problem8):
    """max_staleness=1 is version-synchronous: the straggler costs stall
    time, not quality — per-node updates are the lockstep updates."""
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    metric = functools.partial(bias_to_optimum, x_star=problem8.x_star)
    r_h = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=60,
                   scenario="homogeneous", metric_fn=metric)
    r_s = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=60,
                   scenario="straggler_1slow", seed=0, metric_fn=metric)
    assert r_s.stall_time.sum() > 0 and r_s.sim_time > r_h.sim_time
    assert r_s.final_metric == pytest.approx(r_h.final_metric, rel=0.05)


def test_straggler_stall_accounting_pinned(problem8):
    """A synchronous barrier behind a 1-slow node must stretch sim time AND
    accrue stall on the fast nodes — including the terminal tail (nodes
    still SSP-blocked when the run ends have been stalling since they last
    became ready; the flush must count it)."""
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    r_h = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=40,
                   scenario="homogeneous")
    r_s = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=40,
                   scenario="straggler_1slow", seed=0)
    assert r_s.stall_time.sum() > 0
    assert r_s.sim_time > r_h.sim_time
    # the 4x straggler gates every BSP round: each fast node spends the
    # bulk of the horizon blocked, so total stall must be of the same order
    # as (n - 1) * sim_time — not just a rounding residue
    assert r_s.stall_time.sum() > 0.5 * (8 - 1) * r_s.sim_time
    # every fast node accrued stall; the straggler itself never waits
    assert (r_s.stall_time[1:] > 0).all()
    assert r_s.stall_time[0] == 0.0


# ---------------------------------------------------------------------------
# Failures: reroute, rescale, churn
# ---------------------------------------------------------------------------


def _restrict_for(problem):
    def restrict(idx):
        sel = np.asarray(idx)
        sub = dataclasses.replace(problem, A=problem.A[sel], b=problem.b[sel])
        return lambda x, _s: sub.grad(x)

    return restrict


def test_failstop_within_budget_reroutes(problem8):
    # n=8 with 1 dead == n//8: reroute (the plan_recovery boundary)
    sc = Scenario(name="fs1", events=(FailStop(at_step=4, nodes=(3,)),))
    opt = make_optimizer(OptimizerConfig(algorithm="dmsgd", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=12, scenario=sc)
    assert r.recovery_mode == "reroute"
    assert r.n_nodes == 8 and r.dead == (3,)
    assert r.steps[3] <= 5  # frozen at failure
    alive = [i for i in range(8) if i != 3]
    assert (r.steps[alive] >= 12).all()
    assert effective_batch_fraction(r) < 1.0


def test_failstop_quarter_rescales(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    metric = functools.partial(bias_to_optimum, x_star=problem8.x_star)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=15,
                 scenario="failstop_quarter", metric_fn=metric,
                 restrict=_restrict_for(problem8))
    assert r.recovery_mode == "rescale"
    assert r.n_nodes == 6 and r.n_start == 8
    assert r.kept == (2, 3, 4, 5, 6, 7)  # every survivor: ring builds at any n
    assert jax.tree.leaves(r.params)[0].shape[0] == 6
    assert (r.steps >= 15).all()
    assert np.isfinite(r.final_metric)
    # deterministic end to end
    r2 = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=15,
                  scenario="failstop_quarter", metric_fn=metric,
                  restrict=_restrict_for(problem8))
    assert _tree_equal(r.params, r2.params) and r.final_metric == r2.final_metric


def test_rescale_without_restrict_raises(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    with pytest.raises(ValueError, match="restrict"):
        _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=15,
                 scenario="failstop_quarter")


def test_churn_rejoin_recovers(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=24,
                 scenario="churn", seed=1)
    kinds = [e["event"] for e in r.events_log]
    assert any(k.startswith("failstop") for k in kinds)
    assert any(k.startswith("rejoin") for k in kinds)
    assert any(k.startswith("slowdown") for k in kinds)
    assert r.dead == ()  # everyone is back
    assert (r.steps >= 24).all()
    assert bool(jnp.all(jnp.isfinite(r.params)))


def test_rejoin_does_not_double_schedule(problem8):
    """A node that fails and rejoins while its pre-failure completion event
    is still queued must not end up with two live events (it would then
    permanently step at ~2x rate)."""
    from repro.sim import Rejoin

    sc = Scenario(
        name="flap",
        events=(FailStop(at_step=5, nodes=(1,)), Rejoin(at_step=5, nodes=(1,))),
    )
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=20, scenario=sc)
    assert r.dead == ()
    # the flapping node runs at the same rate as everyone else afterwards
    assert int(r.steps[1]) <= int(r.steps.max()) + 1
    assert int(r.steps[1]) - int(r.steps.min()) <= 2


def test_trace_has_no_duplicate_final_tick(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=12,
                 scenario="homogeneous", record_dt=4.0)
    ticks = [e["t"] for e in r.trace]
    assert len(ticks) == len(set(ticks))
    assert r.trace[-1]["min_step"] == 12


def test_trace_recording(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    metric = functools.partial(bias_to_optimum, x_star=problem8.x_star)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=12,
                 scenario="homogeneous", record_dt=4.0, metric_fn=metric)
    assert len(r.trace) >= 3
    for e in r.trace:
        assert {"t", "min_step", "max_step", "consensus", "metric"} <= set(e)
    assert r.trace[-1]["min_step"] == 12
    # homogeneous bookkeeping
    assert r.sim_time == pytest.approx(12.0)
    assert r.stall_time.sum() == 0.0
    assert effective_batch_fraction(r) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Wall-clock projection
# ---------------------------------------------------------------------------


def test_wallclock_projection_orders_scenarios(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    topo = build_topology("ring", 8)
    r_h = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=20,
                   scenario="homogeneous")
    r_s = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=20,
                   scenario="straggler_1slow", seed=0)
    p_h = project_wallclock(r_h, topo, opt=opt, grad_fn=_grad(problem8))
    p_s = project_wallclock(r_s, topo, opt=opt, grad_fn=_grad(problem8))
    for key in ("step_time_s", "wallclock_s", "steps_per_s", "dominant",
                "compute_s", "memory_s", "collective_s", "stall_s"):
        assert key in p_h
    assert p_h["step_time_s"] > 0
    assert p_s["wallclock_s"] > p_h["wallclock_s"]  # straggler costs time
    assert p_s["steps_per_s"] < p_h["steps_per_s"]
    assert p_h["stall_s"] == 0.0 and p_s["stall_s"] > 0.0


def test_wallclock_price_floor_is_physically_plausible(problem8):
    """Pricing the 30-dim toy on raw rooflines projected ~1e9 steps/s into
    BENCH_sim.json; the per-step price must be floored by the
    work-independent launch/dispatch latency so projected throughput stays
    inside physical bounds."""
    from repro.sim import MIN_STEP_S

    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    topo = build_topology("ring", 8)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=20,
                 scenario="homogeneous")
    p = project_wallclock(r, topo, opt=opt, grad_fn=_grad(problem8))
    assert p["step_time_s"] >= MIN_STEP_S
    assert p["dominant"] == "latency"  # the toy's roofline is below the floor
    assert p["roofline_s"] < p["step_time_s"]
    # n nodes each bounded by 1/MIN_STEP_S steps per second
    assert 0 < p["steps_per_s"] <= 8 / MIN_STEP_S * (1 + 1e-6)
    # the raw roofline bound stays available for real model configs
    from repro.sim import payload_bytes, step_time_seconds

    raw = step_time_seconds(topo, payload_bytes(r.params), min_step_s=0.0)
    assert raw["step_time_s"] == raw["roofline_s"] < MIN_STEP_S


def test_wallclock_calibration_from_dryrun_pinned(problem8, tmp_path):
    """Sim-calibrated wallclock (ROADMAP item): a measured per-step time
    from a ``launch.train`` run replaces the roofline price outright, so
    scenario projections carry real units.  Pinned: wallclock_s ==
    sim_time x measured_step_s exactly, dominant == "measured", and every
    accepted calibration input form (float / dict / json path) agrees."""
    import json

    from repro.sim import calibrate_from_dryrun

    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    topo = build_topology("ring", 8)
    r = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=20,
                 scenario="straggler_1slow", seed=0)

    measured = 0.05  # 50 ms/step, as launch.train --measure-json reports it
    path = tmp_path / "measure.json"
    path.write_text(json.dumps({"measured_step_s": measured}))
    assert calibrate_from_dryrun(measured) == measured
    assert calibrate_from_dryrun({"measured_step_s": measured}) == measured
    assert calibrate_from_dryrun(str(path)) == measured
    with pytest.raises(ValueError):
        calibrate_from_dryrun({"wrong_key": 1.0})
    with pytest.raises(ValueError):
        calibrate_from_dryrun(0.0)

    p = project_wallclock(r, topo, opt=opt, grad_fn=_grad(problem8),
                          measured_step_s=calibrate_from_dryrun(str(path)))
    assert p["dominant"] == "measured"
    assert p["step_time_s"] == measured
    assert p["wallclock_s"] == pytest.approx(r.sim_time * measured)
    total_steps = int(r.steps[r.alive].sum())
    assert p["steps_per_s"] == pytest.approx(total_steps / (r.sim_time * measured))
    # roofline terms stay in the report for reference
    assert {"compute_s", "memory_s", "collective_s", "roofline_s"} <= set(p)


def test_event_engine_compression_threads_channel_state(problem8):
    """simulate(compression=...) runs both engines: stateless compressors
    leave the trajectory near-baseline, and top-k's error-feedback
    residuals thread through the virtual stacked step (non-zero after the
    run, and compression=None stays bit-exact with the pre-compression
    engine)."""
    x0 = jnp.zeros((8, 6), jnp.float32)
    metric = functools.partial(bias_to_optimum, x_star=problem8.x_star)
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam-sa", momentum=0.8))
    base = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=40,
                    scenario="straggler_1slow_async", seed=0, metric_fn=metric)
    again = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=40,
                     scenario="straggler_1slow_async", seed=0, metric_fn=metric,
                     compression=None)
    np.testing.assert_array_equal(np.asarray(base.params), np.asarray(again.params))
    bf16 = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=40,
                    scenario="straggler_1slow_async", seed=0, metric_fn=metric,
                    compression="bf16")
    assert np.isfinite(bf16.final_metric)
    assert bf16.final_metric <= base.final_metric * 2.0 + 1e-3
    # delayed engine too (stale_gossip_* scenarios)
    k2 = _sim(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=40,
                  scenario="stale_gossip_k2", seed=0, metric_fn=metric,
                  compression="int8")
    assert np.isfinite(k2.final_metric)


def test_event_engine_decentlam_sa_async_straggler_converges(problem8):
    """The headline repair: under bounded-staleness asynchrony (SSP-8)
    decentlam diverges while decentlam-sa — damping on the incident-edge
    version gaps the engine feeds it — stays at baseline quality."""
    x0 = jnp.zeros((8, 6), jnp.float32)
    metric = functools.partial(bias_to_optimum, x_star=problem8.x_star)
    sa = make_optimizer(OptimizerConfig(algorithm="decentlam-sa", momentum=0.8))
    r = _sim(sa, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=80,
                 scenario="straggler_1slow_async", seed=0, metric_fn=metric)
    assert np.isfinite(r.final_metric) and r.final_metric < 1.0
    assert np.isfinite(r.final_consensus)
    dm = make_optimizer(OptimizerConfig(algorithm="dmsgd", momentum=0.8))
    r_dm = _sim(dm, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=80,
                    scenario="straggler_1slow_async", seed=0, metric_fn=metric)
    assert r.final_metric <= r_dm.final_metric * 1.5


def test_is_diverged_marks_unrankable_runs():
    """The benchmark nulls quality metrics for diverged runs; the detector
    must catch non-finite, missing, AND finite-but-left-the-basin biases
    (the 1.6e26 values BENCH_sim.json used to report as 'quality')."""
    from repro.sim import is_diverged

    assert is_diverged(float("inf"))
    assert is_diverged(float("nan"))
    assert is_diverged(None)
    assert is_diverged(1.6e26)
    assert is_diverged(0.001, 2e7)  # any metric past the basin flags the run
    assert not is_diverged(0.001, 0.9)


def test_scenario_registry_contents():
    for name in ("homogeneous", "straggler_1slow", "failstop_quarter", "churn",
                 "straggler_tail",
                 "stale_gossip_k1", "stale_gossip_k2", "stale_gossip_k4"):
        sc = get_scenario(name, 8, 100)
        assert sc.name == name
        assert len(sc.duration_models(8)) == 8
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope", 8, 100)


# ---------------------------------------------------------------------------
# Vectorized engine == per-node reference engine (tentpole acceptance)
# ---------------------------------------------------------------------------


def _full_result_equal(r1, r2) -> bool:
    return (
        _tree_equal(r1.params, r2.params)
        and _tree_equal(r1.opt_state, r2.opt_state)
        and (r1.steps == r2.steps).all()
        and (r1.stall_time == r2.stall_time).all()
        and r1.sim_time == r2.sim_time
        and r1.n_nodes == r2.n_nodes
        and r1.recovery_mode == r2.recovery_mode
        and r1.dead == r2.dead
        and r1.kept == r2.kept
        and r1.trace == r2.trace
        and r1.events_log == r2.events_log
        and r1.final_metric == r2.final_metric
        and r1.final_consensus == r2.final_consensus
    )


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_vectorized_engine_bit_exact_with_pernode(problem8, algorithm):
    """The node-batched engine must reproduce the per-node reference loop
    bit-for-bit — every algorithm x every event scenario in the registry,
    full SimResult (params, state, steps, stall accounting, trace, events).
    The two engines are independent implementations (ring mailboxes +
    grouped jitted steps vs deque mailboxes + one launch per event), so
    agreement here pins the whole execution model."""
    opt = make_optimizer(OptimizerConfig(algorithm=algorithm, momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    metric = functools.partial(bias_to_optimum, x_star=problem8.x_star)
    for scenario in EVENT_SCENARIOS:
        kw = dict(lr=1e-2, n_steps=15, scenario=scenario, seed=3,
                  record_dt=3.0, metric_fn=metric,
                  restrict=_restrict_for(problem8))
        r_ref = _sim(opt, "ring", 8, x0, _grad(problem8), engine="pernode", **kw)
        r_vec = _sim(opt, "ring", 8, x0, _grad(problem8), engine="vectorized", **kw)
        assert _full_result_equal(r_ref, r_vec), (algorithm, scenario)


def test_vectorized_engine_bit_exact_on_time_varying_topology(problem8):
    """Same pin on a sparse time-varying graph (phase indices + edge-class
    neighbor maps must agree between the engines) and under compression
    (channel-state rows thread through the ring mailboxes)."""
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam-sa", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    for topology, comp in [("one-peer-exp", None), ("one-peer-ring", None),
                           ("ring", "topk:0.5")]:
        kw = dict(lr=1e-2, n_steps=20, scenario="straggler_1slow_async",
                  seed=0, compression=comp)
        r_ref = _sim(opt, topology, 8, x0, _grad(problem8), engine="pernode", **kw)
        r_vec = _sim(opt, topology, 8, x0, _grad(problem8), engine="vectorized", **kw)
        assert _full_result_equal(r_ref, r_vec), (topology, comp)


# ---------------------------------------------------------------------------
# SimSpec front door
# ---------------------------------------------------------------------------


def test_legacy_kwargs_signature_removed(problem8):
    """The pre-SimSpec kwargs-pile signature completed its one-release
    deprecation window: a non-SimSpec second argument is a clean TypeError
    naming the supported call shape, not a silent misparse."""
    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    x0 = jnp.zeros((8, 6), jnp.float32)
    with pytest.raises(TypeError, match="SimSpec"):
        simulate(opt, "ring", 8, x0, _grad(problem8), lr=1e-2, n_steps=12)


def test_simspec_validation_and_call_shape(problem8):
    opt = make_optimizer(OptimizerConfig(algorithm="dsgd"))
    x0 = jnp.zeros((8, 6), jnp.float32)
    with pytest.raises(ValueError, match="unknown engine"):
        SimSpec(engine="warp")
    with pytest.raises(ValueError, match="unknown sparse mode"):
        SimSpec(sparse="topk")
    with pytest.raises(ValueError, match="sparse_crossover"):
        SimSpec(sparse="exact", sparse_crossover=0.0)
    spec = SimSpec(topology="ring", n=8, n_steps=5)
    # SimSpec calls take exactly (opt, spec, params0, grad_fn) — no kwargs
    with pytest.raises(TypeError, match="exactly four"):
        simulate(opt, spec, x0, _grad(problem8), lr=1e-2)
    with pytest.raises(TypeError, match="exactly four"):
        simulate(opt, spec, x0)
    # engine="pernode"/"auto" both run; spec is reusable (frozen value)
    r1 = simulate(opt, spec, x0, _grad(problem8))
    r2 = simulate(opt, spec, x0, _grad(problem8))
    assert _full_result_equal(r1, r2)


# ---------------------------------------------------------------------------
# Mailbox semantics (pinned)
# ---------------------------------------------------------------------------


def test_mailbox_retained_depth_semantics():
    """Publication keeps exactly the last ``depth`` snapshots (oldest
    evicted, O(1) via deque maxlen) and ``_visible`` scans newest-first
    under the publication deadline and the SSP version cap, falling back to
    the oldest retained entry."""
    from repro.sim.runner import _new_mailboxes, _visible

    depth = 3
    boxes = _new_mailboxes(2, depth)
    box = boxes[0]
    for v in range(5):  # versions 0..4 published at t = v
        box.append((v, float(v), f"x{v}", f"s{v}", f"c{v}"))
    # retained-depth: exactly the last `depth`, oldest first
    assert [snap[0] for snap in box] == [2, 3, 4]
    # newest visible under deadline + version cap
    assert _visible(box, deadline=10.0, version_cap=10)[0] == 4
    assert _visible(box, deadline=3.5, version_cap=10)[0] == 3
    assert _visible(box, deadline=10.0, version_cap=3)[0] == 3
    assert _visible(box, deadline=3.0, version_cap=2)[0] == 2  # pub == deadline ok
    # nothing qualifies -> oldest retained (the SSP fallback)
    assert _visible(box, deadline=0.5, version_cap=10)[0] == 2
    assert boxes[1] is not box and len(boxes[1]) == 0
