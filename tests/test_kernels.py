"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret=True on CPU (the kernels' TPU lowering path is exercised on real
hardware; the *math* is identical)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StackedChannel, build_topology, make_stacked_mean
from repro.core.optimizers import ALGORITHMS, OptimizerConfig, make_optimizer
from repro.core.update_spec import run_update, update_spec
from repro.kernels.fused_update import decentlam_update, make_stage
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import reference_attention
from repro.kernels.mlstm_chunk.ops import mlstm
from repro.kernels.mlstm_chunk.ref import (
    mlstm_chunked,
    mlstm_decode_step,
    mlstm_sequential,
)

RNG = np.random.default_rng(0)


def _rand(shape, dt):
    return jnp.asarray(RNG.standard_normal(shape), dt)


FLASH_CASES = [
    # B, Sq, Sk, H, Hkv, hd, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 4, 4, 32, True, 64, jnp.float32),
    (2, 100, 100, 2, 1, 64, True, 0, jnp.bfloat16),
    (1, 128, 128, 2, 2, 64, False, 0, jnp.float32),
    (1, 64, 192, 2, 2, 64, False, 0, jnp.float32),  # cross-ish Sq != Sk
    (2, 160, 160, 8, 2, 32, True, 96, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_reference(case):
    B, Sq, Sk, H, Hkv, hd, causal, window, dt = case
    q = _rand((B, Sq, H, hd), dt)
    k = _rand((B, Sk, Hkv, hd), dt)
    v = _rand((B, Sk, Hkv, hd), dt)
    out = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_attention_block_size_invariance():
    q = _rand((1, 256, 2, 64), jnp.float32)
    k = _rand((1, 256, 2, 64), jnp.float32)
    v = _rand((1, 256, 2, 64), jnp.float32)
    outs = [
        np.asarray(
            flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
        )
        for (bq, bk) in [(64, 64), (128, 64), (64, 128), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


MLSTM_CASES = [
    (2, 3, 128, 32, 48, 32),
    (1, 2, 256, 64, 64, 64),
    (1, 1, 64, 16, 16, 64),
]


@pytest.mark.parametrize("case", MLSTM_CASES)
def test_mlstm_chunked_matches_sequential(case):
    B, H, S, dk, dv, chunk = case
    q, k = _rand((B, H, S, dk), jnp.float32), _rand((B, H, S, dk), jnp.float32)
    v = _rand((B, H, S, dv), jnp.float32)
    ir = _rand((B, H, S), jnp.float32)
    fr = 2.0 + _rand((B, H, S), jnp.float32)
    h_seq, st_seq = mlstm_sequential(q, k, v, ir, fr)
    h_ch, st_ch = mlstm_chunked(q, k, v, ir, fr, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_seq), np.asarray(h_ch), atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(st_seq["C"]), np.asarray(st_ch["C"]), atol=2e-4
    )


@pytest.mark.parametrize("case", MLSTM_CASES[:2])
def test_mlstm_pallas_matches_ref(case):
    B, H, S, dk, dv, chunk = case
    q, k = _rand((B, H, S, dk), jnp.float32), _rand((B, H, S, dk), jnp.float32)
    v = _rand((B, H, S, dv), jnp.float32)
    ir = _rand((B, H, S), jnp.float32)
    fr = 2.0 + _rand((B, H, S), jnp.float32)
    h_ref, st_ref = mlstm(q, k, v, ir, fr, chunk=chunk, impl="ref")
    h_pl, st_pl = mlstm(q, k, v, ir, fr, chunk=chunk, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_pl), atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(st_ref["C"]), np.asarray(st_pl["C"]), atol=2e-4
    )


def test_mlstm_decode_extends_sequence():
    B, H, S, dk, dv = 1, 2, 65, 32, 32
    q, k = _rand((B, H, S, dk), jnp.float32), _rand((B, H, S, dk), jnp.float32)
    v = _rand((B, H, S, dv), jnp.float32)
    ir = _rand((B, H, S), jnp.float32)
    fr = 2.0 + _rand((B, H, S), jnp.float32)
    h_all, _ = mlstm_sequential(q, k, v, ir, fr)
    _, st = mlstm_chunked(
        q[:, :, : S - 1], k[:, :, : S - 1], v[:, :, : S - 1],
        ir[:, :, : S - 1], fr[:, :, : S - 1], chunk=16,
    )
    h1, _ = mlstm_decode_step(
        q[:, :, S - 1], k[:, :, S - 1], v[:, :, S - 1],
        ir[:, :, S - 1], fr[:, :, S - 1], st,
    )
    np.testing.assert_allclose(
        np.asarray(h1), np.asarray(h_all[:, :, S - 1]), atol=2e-4
    )


@pytest.mark.parametrize(
    "shape,dt",
    [((1000,), jnp.float32), ((33, 77), jnp.float32), ((8, 128), jnp.bfloat16),
     ((64, 1024), jnp.float32)],
)
def test_decentlam_update_kernel(shape, dt):
    x = _rand(shape, dt)
    mix = x - 0.01 * jnp.sign(x)
    m = _rand(shape, jnp.float32)
    lr = jnp.float32(0.02)
    p_ref, m_ref = decentlam_update({"w": x}, {"w": mix}, {"w": m}, lr, beta=0.9, impl="ref")
    p_pl, m_pl = decentlam_update(
        {"w": x}, {"w": mix}, {"w": m}, lr, beta=0.9, impl="pallas_interpret"
    )
    np.testing.assert_allclose(
        np.asarray(p_ref["w"], np.float32), np.asarray(p_pl["w"], np.float32),
        atol=1e-2 if dt == jnp.bfloat16 else 1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(m_ref["w"]), np.asarray(m_pl["w"]), atol=1e-2
    )


@pytest.mark.parametrize("sg", [1.0, 0.37, 0.0])
def test_decentlam_sa_post_fused_matches_reference(sg):
    """Fused-vs-reference parity for the staleness-aware op at real damping
    factors (the fused engine receives sg as the 4th SMEM scalar — the
    per-node value inside shard_map).  At sg=1 both must also equal the
    plain decentlam_post (the bit-exactness hinge)."""
    from repro.core.update_spec import MathCtx, reference_stage
    from repro.kernels.fused_update import make_stage

    rng = np.random.default_rng(17)
    ops = {
        "x": jnp.asarray(rng.standard_normal((9, 33)), jnp.float32),
        "mix": jnp.asarray(rng.standard_normal((9, 33)), jnp.float32),
        "m": jnp.asarray(rng.standard_normal((9, 33)), jnp.float32),
        "g": jnp.asarray(rng.standard_normal((9, 33)), jnp.float32),
    }
    scalars = {
        "lr": jnp.float32(0.02),
        "gs": jnp.float32(1.0),
        "r": jnp.float32(1.0),
        "sg": jnp.float32(sg),
    }
    ctx = MathCtx(beta=0.9)
    ref = reference_stage(
        "post", "decentlam_sa_post", ctx, ops, scalars, ops["x"]
    )
    fus = make_stage("pallas_interpret")(
        "post", "decentlam_sa_post", ctx, ops, scalars, ops["x"]
    )
    for k in ("x", "m"):
        np.testing.assert_allclose(
            np.asarray(ref[k]), np.asarray(fus[k]), rtol=1e-5, atol=1e-5,
            err_msg=k,
        )
    if sg == 1.0:
        plain = reference_stage(
            "post", "decentlam_post", ctx,
            {k: ops[k] for k in ("x", "mix", "m")}, scalars, ops["x"],
        )
        np.testing.assert_array_equal(np.asarray(ref["x"]), np.asarray(plain["x"]))
        np.testing.assert_array_equal(np.asarray(ref["m"]), np.asarray(plain["m"]))


def test_decentlam_update_semantics():
    """x_new must equal mix - lr*beta*m (algebraic identity of eq. 17 tail)."""
    x = _rand((256,), jnp.float32)
    mix = _rand((256,), jnp.float32)
    m = _rand((256,), jnp.float32)
    lr = jnp.float32(0.1)
    p, m2 = decentlam_update({"w": x}, {"w": mix}, {"w": m}, lr, beta=0.9, impl="ref")
    np.testing.assert_allclose(
        np.asarray(p["w"]), np.asarray(mix - 0.1 * 0.9 * m), atol=1e-5
    )


# ---------------------------------------------------------------------------
# Fused multi-algorithm engine: every algorithm's full update tail through
# the Pallas stage kernels (interpret mode) vs the stacked reference step.
# ---------------------------------------------------------------------------

N_NODES = 8


def _fused_vs_reference(cfg: OptimizerConfig, dt, *, steps=1, lr=0.01):
    """Run `steps` of the stacked harness via both paths and compare."""
    rng = np.random.default_rng(7)
    topo = build_topology("exp", N_NODES)
    gossip, mean = StackedChannel(topo), make_stacked_mean(N_NODES)
    params = {
        "w": jnp.asarray(rng.standard_normal((N_NODES, 37)), dt),
        "b": jnp.asarray(rng.standard_normal((N_NODES, 5, 3)), dt),
    }
    opt = make_optimizer(cfg)
    spec = update_spec(cfg)
    stage = make_stage("pallas_interpret")

    p_ref, p_fus = params, params
    s_ref, s_fus = opt.init(params), opt.init(params)
    for k in range(steps):
        grads = {
            kk: jnp.asarray(rng.standard_normal(v.shape), jnp.float32)
            for kk, v in params.items()
        }
        p_ref, s_ref, _ = opt.step(
            p_ref, grads, s_ref, lr=lr, step_idx=jnp.int32(k),
            gossip=gossip, mean=mean,
        )
        x, s_fus, _ = run_update(
            spec, cfg, x=p_fus, g=grads, state=s_fus, lr=lr,
            step_idx=jnp.int32(k), gossip=gossip, mean=mean,
            comp_state=(), stage=stage,
        )
        p_fus = jax.tree.map(lambda p, v: v.astype(p.dtype), p_fus, x)

    # the momentum recovery (x - mix)/lr amplifies roundoff by 1/lr per
    # step, so state comparisons need a relative component
    tol = 4e-2 if dt == jnp.bfloat16 else 2e-5
    rtol = 2e-3
    for kk in params:
        np.testing.assert_allclose(
            np.asarray(p_ref[kk], np.float32),
            np.asarray(p_fus[kk], np.float32),
            rtol=rtol,
            atol=tol,
            err_msg=f"{cfg.algorithm} params[{kk}]",
        )
    for sk in s_ref:
        for kk in params:
            np.testing.assert_allclose(
                np.asarray(s_ref[sk][kk], np.float32),
                np.asarray(s_fus[sk][kk], np.float32),
                rtol=rtol,
                atol=tol,
                err_msg=f"{cfg.algorithm} state[{sk}][{kk}]",
            )


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("algo", ALGORITHMS)
def test_fused_engine_matches_reference(algo, dt):
    cfg = OptimizerConfig(
        algorithm=algo, momentum=0.9, weight_decay=0.01, slowmo_period=2
    )
    _fused_vs_reference(cfg, dt, steps=2)


@pytest.mark.parametrize(
    "cfg",
    [
        OptimizerConfig(algorithm="decentlam", momentum=0.9, nesterov=True),
        OptimizerConfig(algorithm="dmsgd", momentum=0.9, nesterov=True,
                        weight_decay=0.1, decoupled_wd=True),
        OptimizerConfig(algorithm="decentlam", momentum=0.9, grad_clip=0.5),
        OptimizerConfig(algorithm="pmsgd-lars", momentum=0.9,
                        weight_decay=1e-4, lars_trust=0.02),
        OptimizerConfig(algorithm="dmsgd", momentum=0.9, lars=True,
                        weight_decay=1e-4, grad_clip=1.0),
        OptimizerConfig(algorithm="da-dmsgd", momentum=0.9, weight_decay=0.1,
                        decoupled_wd=True),
    ],
    ids=["nesterov", "nesterov-decoupled-wd", "clip", "lars", "lars-clip",
         "two-gossip-decoupled-wd"],
)
def test_fused_engine_feature_flags(cfg):
    """Nesterov / weight decay / clip / LARS fold into the fused stages."""
    _fused_vs_reference(cfg, jnp.float32, steps=2)
