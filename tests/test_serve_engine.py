"""Continuous-batching scheduler: slot mechanics pinned against the
sequential per-request oracle.

* engine output (variable-length prompts, staggered submissions, queue
  deeper than the slot count) is **token-identical** to prefilling each
  request alone at its exact length and greedy-decoding sequentially;
* snapshot swaps between request waves: completions produced before an
  accepted publish use the old weights, completions after use the new —
  each side matching its own oracle — and the measured swap count is 1;
* an engine waiting on a gated publisher ticks without decoding until the
  first version ships;
* ``eos_id`` terminates a slot early at exactly the oracle's sequence;
* ``greedy_decode_loop`` unit semantics (token threading + position
  advance) on a synthetic decode_fn.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_lm
from repro.core.planes import PlaneLayout
from repro.models import transformer as T
from repro.models.layers import TPContext
from repro.serve import Request, ServeEngine, WeightPublisher, greedy_decode_loop

CFG = tiny_lm(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
              vocab_size=64)
RT = T.RuntimeConfig(dtype="float32", remat=False)
TP1 = TPContext(size=1)
MAX_PROMPT, MAX_NEW = 12, 6
TL = MAX_PROMPT + MAX_NEW


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _params(seed=0):
    return T.init_params(jax.random.key(seed), CFG, tp=1)


def _prompts(n, seed=0):
    r = np.random.default_rng(seed)
    return [
        r.integers(0, CFG.vocab_size, size=int(r.integers(2, MAX_PROMPT + 1)))
        .astype(np.int32)
        for _ in range(n)
    ]


def _oracle(params, prompt, n_steps):
    """Sequential reference: exact-length prefill, then greedy decode via
    the shared loop — re-feeding the last prompt token at its true position
    exactly like slot admission does."""
    n = prompt.size
    _, cache = jax.jit(
        lambda p, b: T.prefill(p, b, CFG, TP1, RT, target_len=TL)
    )(params, {"tokens": jnp.asarray(prompt[None, :])})
    decode_fn = jax.jit(
        lambda p, tok, c, t: T.decode_step(p, tok, c, t, CFG, TP1, RT,
                                           target_len=TL)
    )
    toks, _ = greedy_decode_loop(
        decode_fn, params, cache,
        jnp.asarray(prompt[None, -1:]), jnp.int32(n - 1), n_steps,
    )
    return np.asarray(toks[0])


def test_engine_matches_sequential_oracle():
    params = _params()
    prompts = _prompts(7, seed=1)
    eng = ServeEngine(CFG, _mesh(), slots=3, max_prompt=MAX_PROMPT,
                      max_new=MAX_NEW, runtime=RT, params=params)
    # staggered load: 4 up front, 3 more mid-flight, queue > slots
    for i in range(4):
        eng.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=MAX_NEW))
    for _ in range(2):
        eng.tick()
    for i in range(4, 7):
        eng.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=MAX_NEW))
    done = eng.run_until_drained()
    assert sorted(c.rid for c in done) == list(range(7))
    for c in done:
        np.testing.assert_array_equal(
            c.tokens, _oracle(params, prompts[c.rid], MAX_NEW), str(c.rid)
        )
        assert c.submitted_s <= c.admitted_s <= c.finished_s
    st = eng.stats()
    assert st["completed"] == 7 and st["swaps"] == 0
    assert st["prefills"] >= 2  # two admission waves at minimum
    assert eng.idle and not eng.tick()


def test_engine_snapshot_swap_between_waves():
    """Wave 1 runs on published v1, wave 2 on v2; each matches its own
    oracle and exactly one swap (v1 -> v2) is counted."""
    params_a, params_b = _params(0), _params(1)
    lay = PlaneLayout.build(params_a)
    pub = WeightPublisher(lay, gap_threshold=0, check_consistency=True)
    prompts = _prompts(4, seed=2)
    eng = ServeEngine(CFG, _mesh(), slots=2, max_prompt=MAX_PROMPT,
                      max_new=MAX_NEW, runtime=RT, publisher=pub)

    assert pub.offer(params_a, version=1, gap=0)
    for i in range(2):
        eng.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=MAX_NEW))
    eng.run_until_drained()
    assert eng.version == 1

    assert pub.offer(params_b, version=2, gap=0)
    for i in range(2, 4):
        eng.submit(Request(rid=i, tokens=prompts[i], max_new_tokens=MAX_NEW))
    done = {c.rid: c for c in eng.run_until_drained()}

    assert eng.version == 2 and eng.stats()["swaps"] == 1
    for rid, ref in [(0, params_a), (1, params_a), (2, params_b), (3, params_b)]:
        np.testing.assert_array_equal(
            done[rid].tokens, _oracle(ref, prompts[rid], MAX_NEW), str(rid)
        )


def test_engine_waits_on_gated_publisher():
    """Before the consensus gate clears the first version, ticks are
    waiting ticks — no prefill, no decode; once it ships, the queue drains."""
    params = _params()
    lay = PlaneLayout.build(params)
    pub = WeightPublisher(lay, gap_threshold=0)
    prompt = _prompts(1, seed=3)[0]
    eng = ServeEngine(CFG, _mesh(), slots=2, max_prompt=MAX_PROMPT,
                      max_new=3, runtime=RT, publisher=pub)
    eng.submit(Request(rid=0, tokens=prompt, max_new_tokens=3))

    assert not pub.offer(params, version=1, gap=5)  # gate holds it back
    for _ in range(3):
        assert eng.tick()  # pending work, but nothing runnable
    assert eng.waiting_ticks == 3 and eng.decode_batches == 0

    assert pub.offer(params, version=2, gap=0)
    done = eng.run_until_drained()
    np.testing.assert_array_equal(done[0].tokens, _oracle(params, prompt, 3))


def test_engine_eos_early_exit():
    params = _params()
    prompt = _prompts(1, seed=4)[0]
    ref = _oracle(params, prompt, MAX_NEW)
    eos = int(ref[2])  # make the oracle's 3rd token (or earlier) the stop
    stop = int(np.argmax(ref == eos))  # first occurrence
    eng = ServeEngine(CFG, _mesh(), slots=2, max_prompt=MAX_PROMPT,
                      max_new=MAX_NEW, runtime=RT, params=params, eos_id=eos)
    eng.submit(Request(rid=0, tokens=prompt, max_new_tokens=MAX_NEW))
    done = eng.run_until_drained()
    np.testing.assert_array_equal(done[0].tokens, ref[: stop + 1])


def test_engine_rejects_oversized_requests():
    eng = ServeEngine(CFG, _mesh(), slots=1, max_prompt=4, max_new=2,
                      runtime=RT, params=_params())
    with pytest.raises(AssertionError):
        eng.submit(Request(rid=0, tokens=np.arange(5, dtype=np.int32),
                           max_new_tokens=1))
    with pytest.raises(AssertionError):
        eng.submit(Request(rid=0, tokens=np.arange(3, dtype=np.int32),
                           max_new_tokens=3))


def test_decode_per_slot_t_sinusoid_path():
    """The sinusoid (rope_theta=0) embed path takes (B,) positions: each
    slot of a heterogeneous-t batched decode matches its own scalar-t
    decode off a solo prefill."""
    import dataclasses

    cfg = dataclasses.replace(CFG, rope_theta=0.0)
    params = T.init_params(jax.random.key(0), cfg, tp=1)
    rng = np.random.default_rng(5)
    B, S = 3, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    _, cache = T.prefill(params, {"tokens": toks[:, :S]}, cfg, TP1, RT,
                         target_len=TL)
    tvec = jnp.asarray([S, S - 2, S - 1], jnp.int32)
    lg, _ = T.decode_step(params, toks[:, S:S + 1], cache, tvec, cfg, TP1, RT,
                          target_len=TL)
    for i in range(B):
        n = int(tvec[i])
        _, ci = T.prefill(params, {"tokens": toks[i:i + 1, :n]}, cfg, TP1, RT,
                          target_len=TL)
        lg_i, _ = T.decode_step(params, toks[i:i + 1, S:S + 1], ci,
                                jnp.int32(n), cfg, TP1, RT, target_len=TL)
        np.testing.assert_allclose(
            np.asarray(lg[i]), np.asarray(lg_i[0]), atol=1e-4, rtol=1e-4
        )


def test_greedy_decode_loop_threads_tokens_and_positions():
    """Synthetic decode_fn whose argmax is ``(tok + t) % V``: the loop must
    feed each sampled token back and advance per-slot positions by one."""
    V = 11

    def decode_fn(params, tok, cache, t):
        nxt = (tok[:, 0] + t) % V
        return jax.nn.one_hot(nxt, V), cache

    first = jnp.asarray([[3], [7]], jnp.int32)
    t0 = jnp.asarray([2, 5], jnp.int32)
    toks, cache = greedy_decode_loop(decode_fn, None, "cache", first, t0, 4)
    assert cache == "cache"
    expect = np.zeros((2, 4), np.int32)
    cur, t = np.array([3, 7]), np.array([2, 5])
    for s in range(4):
        cur = (cur + t) % V
        expect[:, s] = cur
        t = t + 1
    np.testing.assert_array_equal(np.asarray(toks), expect)
