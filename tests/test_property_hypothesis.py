"""Property-based tests (hypothesis) on the system's invariants.

Requires the ``test`` extra (``pip install -e .[test]``); the module skips
cleanly when hypothesis isn't installed so bare-environment collection
still works.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.topology import metropolis_weights, rho, _classes_from_W  # noqa: E402
from repro.core import (  # noqa: E402
    DelayedStackedChannel,
    StackedChannel,
    build_topology,
    consensus_distance,
)
from repro.kernels.fused_update import decentlam_update  # noqa: E402
from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402
from repro.kernels.flash_attention.ref import reference_attention  # noqa: E402

SET = settings(max_examples=25, deadline=None)


@st.composite
def connected_adjacency(draw, max_n=10):
    n = draw(st.integers(3, max_n))
    adj = np.zeros((n, n), np.int64)
    # random spanning tree guarantees connectivity
    perm = draw(st.permutations(list(range(n))))
    for i in range(1, n):
        j = perm[draw(st.integers(0, i - 1))]
        adj[perm[i], j] = adj[j, perm[i]] = 1
    # extra random edges
    extra = draw(st.integers(0, n))
    for _ in range(extra):
        a = draw(st.integers(0, n - 1))
        b = draw(st.integers(0, n - 1))
        if a != b:
            adj[a, b] = adj[b, a] = 1
    return adj


@SET
@given(connected_adjacency())
def test_metropolis_always_doubly_stochastic(adj):
    W = metropolis_weights(adj)
    n = adj.shape[0]
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), np.ones(n), atol=1e-12)
    assert (W >= -1e-12).all()
    assert rho(W) < 1.0  # connected => mixing


@SET
@given(connected_adjacency())
def test_edge_class_decomposition_reconstructs_W(adj):
    W = metropolis_weights(adj)
    n = W.shape[0]
    R = np.diag(np.diag(W))
    for c in _classes_from_W(W):
        c.validate(n)
        for src, dst in c.pairs:
            R[dst, src] += c.recv_weight[dst]
    np.testing.assert_allclose(R, W, atol=1e-12)


@SET
@given(
    st.sampled_from(["ring", "torus", "exp", "one-peer-exp"]),
    st.integers(0, 1000),
)
def test_gossip_mean_preservation_any_step(name, step):
    topo = build_topology(name, 8)
    ch = StackedChannel(topo)
    rng = np.random.default_rng(step)
    x = jnp.asarray(rng.standard_normal((8, 7)), jnp.float32)
    _, y = ch.apply({}, x, jnp.int32(step))
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y, 0)), np.asarray(jnp.mean(x, 0)), atol=1e-5
    )
    assert float(consensus_distance(y)) <= float(consensus_distance(x)) + 1e-6


@SET
@given(
    st.sampled_from(["ring", "torus", "exp", "one-peer-exp", "full"]),
    st.sampled_from([None, "bf16", "int8", "topk:0.3"]),
    st.integers(1, 4),
    st.integers(1, 4),
)
def test_delayed_channel_delay0_bitexact_and_gap_capped(name, comp, delay, steps):
    """For every topology x compressor: the delayed channel at delay=0 is
    bit-exact with the plain channel, and at delay=k the per-edge version
    gaps never exceed the configured cap (and warm up as min(k, rounds))."""
    topo = build_topology(name, 8)
    plain = StackedChannel(topo, compression=comp)
    delayed0 = DelayedStackedChannel(topo, 0, compression=comp)
    xs = [
        jnp.asarray(
            np.random.default_rng(1000 * delay + t).standard_normal((8, 5)),
            jnp.float32,
        )
        for t in range(steps)
    ]
    st_p, st_0 = plain.init(xs[0]), delayed0.init(xs[0])
    for t, x in enumerate(xs):
        st_p, y_p = plain.apply(st_p, x, jnp.int32(t))
        st_0, y_0 = delayed0.apply(st_0, x, jnp.int32(t))
        np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_0))

    delayed = DelayedStackedChannel(topo, delay, compression=comp)
    st_d = delayed.init(xs[0])
    assert int(np.max(np.asarray(delayed.version_gaps(st_d)))) == 0
    for t, x in enumerate(xs):
        st_d, _ = delayed.apply(st_d, x, jnp.int32(t))
        gaps = np.asarray(delayed.version_gaps(st_d))
        assert gaps.max() <= delay
        # round t mixed payloads exactly min(delay, t) rounds old (warmup)
        assert gaps.max() == min(delay, t)
        assert gaps.min() >= 0


@SET
@given(
    st.integers(1, 3),  # batch
    st.sampled_from([32, 64, 96]),  # seq
    st.sampled_from([1, 2, 4]),  # heads
    st.sampled_from([32, 64]),  # head dim
    st.booleans(),  # causal
    st.sampled_from([0, 16]),  # window
)
def test_flash_attention_property(b, s, h, hd, causal, window):
    rng = np.random.default_rng(b * 1000 + s + h)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    if window and not causal:
        causal = True  # windowed bidir not used by any arch
    out = flash_attention(q, k, v, causal=causal, window=window, bq=32, bk=32,
                          interpret=True)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


@SET
@given(
    st.sampled_from(["ring", "torus", "exp", "one-peer-exp", "full"]),
    st.sampled_from([0, 1, 2, 4]),
    st.floats(0.05, 1.0),
)
def test_decentlam_sa_gap0_bitexact_and_damping_monotone(name, delay, base):
    """Across topology x delay k in {0,1,2,4}: at k=0 decentlam-sa is
    bit-exact with decentlam (params and momentum), at k>0 it stays finite
    where decentlam's estimator is unstable; and the damping schedule is
    exactly 1 at gap 0 and monotone non-increasing in the observed gap."""
    from repro.core import (
        OptimizerConfig,
        make_linear_regression,
        make_optimizer,
    )
    from repro.core.update_spec import staleness_damping
    from repro.sim import run_delayed

    cfg = OptimizerConfig(
        algorithm="decentlam-sa", momentum=0.8, sa_damping=base
    )
    gaps = jnp.arange(0, 9)
    f = np.asarray(staleness_damping(cfg, gaps))
    assert f[0] == 1.0
    assert (np.diff(f) <= 1e-7).all()

    topo = build_topology(name, 8)
    prob = make_linear_regression(n=8, m=6, d=5, seed=delay)
    x0 = jnp.zeros((8, 5), jnp.float32)

    def g(x, s):
        return prob.grad(x)

    p_sa, s_sa, _ = run_delayed(
        make_optimizer(cfg), topo, x0, g, delay=delay, lr=1e-2, n_steps=4
    )
    if delay == 0:
        p_dl, s_dl, _ = run_delayed(
            make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8)),
            topo, x0, g, delay=0, lr=1e-2, n_steps=4,
        )
        np.testing.assert_array_equal(np.asarray(p_sa), np.asarray(p_dl))
        np.testing.assert_array_equal(
            np.asarray(s_sa["m"]), np.asarray(s_dl["m"])
        )
    else:
        assert np.isfinite(np.asarray(p_sa)).all()


@SET
@given(
    st.integers(1, 2000),  # size
    st.floats(0.0, 0.99),  # beta
    st.floats(1e-6, 0.5),  # lr
)
def test_decentlam_update_identity(n, beta, lr):
    """Fused kernel == x - lr*(beta*m + (x - mix)/lr) for any shape/params."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    mix = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.asarray(rng.standard_normal(n), jnp.float32)
    p, m2 = decentlam_update(
        {"w": x}, {"w": mix}, {"w": m}, jnp.float32(lr), beta=beta,
        impl="pallas_interpret",
    )
    g_tilde = (x - mix) / max(lr, 1e-12)
    m_expect = beta * m + g_tilde
    x_expect = x - lr * m_expect
    np.testing.assert_allclose(np.asarray(m2["w"]), np.asarray(m_expect), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(x_expect), rtol=2e-4, atol=2e-4)


@st.composite
def random_pytree(draw):
    """A mixed-dtype parameter pytree with random leaf shapes — nested
    dicts, 1-3D leaves, f32/bf16 buckets, sizes straddling the 1024-lane
    row boundary."""
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n_leaves = draw(st.integers(1, 8))
    tree = {}
    for i in range(n_leaves):
        ndim = draw(st.integers(1, 3))
        shape = tuple(draw(st.integers(1, 40)) for _ in range(ndim))
        dt = draw(st.sampled_from(["float32", "bfloat16"]))
        leaf = jnp.asarray(rng.standard_normal(shape), jnp.dtype(dt))
        group = f"g{i % 3}"
        tree.setdefault(group, {})[f"p{i}"] = leaf
    return tree


@SET
@given(random_pytree(), st.sampled_from(["decentlam", "dmsgd", "pmsgd-lars",
                                         "decentlam-sa"]))
def test_plane_pack_roundtrip_and_parity_any_tree(tree, algo):
    """Flat-plane invariants over arbitrary tree shapes: (1) pack/unpack is
    a lossless round trip in both lowerings; (2) the packed update tail is
    bit-exact with the per-leaf reference tail (LARS row scalars and
    staleness damping included)."""
    import jax

    from repro.core.optimizers import OptimizerConfig, make_optimizer
    from repro.core.planes import LANES, PlaneLayout, plane_scalars
    from repro.core.update_spec import run_update, update_spec

    lay = PlaneLayout.build(tree)
    for impl in ("concat", "gather"):
        planes = lay.pack(tree, impl=impl)
        for key, buf in planes.items():
            assert buf.shape == (lay.rows[key], LANES)
        back = lay.unpack(planes, like=tree)
        assert all(
            jax.tree.leaves(
                jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), back, tree)
            )
        )

    cfg = OptimizerConfig(algorithm=algo, momentum=0.9, weight_decay=0.01,
                          grad_clip=1.0)
    spec = update_spec(cfg)
    rng = np.random.default_rng(7)
    g = jax.tree.map(
        lambda a: jnp.asarray(rng.standard_normal(a.shape), jnp.float32), tree
    )
    state = make_optimizer(cfg).init(tree)

    def gossip(t, step, comp):
        return jax.tree.map(lambda a: 0.5 * a, t), comp

    ng = jnp.int32(1) if spec.staleness_aware else None
    kw = dict(lr=0.01, step_idx=jnp.int32(0), gossip=gossip, mean=lambda t: t,
              comp_state=(), node_gaps=ng)
    x1, s1, _ = run_update(spec, cfg, x=tree, g=g, state=state, **kw)
    x2p, s2p, _ = run_update(
        spec, cfg, x=lay.pack(tree), g=lay.pack(g, dtype=jnp.float32),
        state={k: lay.pack(v, dtype=jnp.float32) for k, v in state.items()},
        scalars=plane_scalars(cfg, lay, tree, g), **kw,
    )
    x2 = lay.unpack(x2p, like=tree)
    assert all(
        jax.tree.leaves(
            jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), x1, x2)
        )
    )
    for sk in s1:
        s2 = lay.unpack(s2p[sk], dtype=jnp.float32)
        assert all(
            jax.tree.leaves(
                jax.tree.map(
                    lambda a, b: bool(jnp.array_equal(a, b)), s1[sk], s2
                )
            )
        ), sk


# ---------------------------------------------------------------------------
# Simulator invariants: sparse neighbor maps + engine parity
# ---------------------------------------------------------------------------


@SET
@given(
    st.sampled_from(["ring", "torus", "exp", "full", "one-peer-exp",
                     "one-peer-ring", "random-match"]),
    st.sampled_from([4, 6, 8, 16]),
    st.integers(0, 5),
)
def test_sparse_in_neighbors_match_dense_union(family, n, seed):
    """The engines' sparse per-edge neighbor map (derived from
    ``Topology.edge_classes``) must equal the dense reference union over
    period phases (``repro.sim.runner._in_neighbors`` scans every W(t) row)
    — for every family, including the time-varying ones, at random sizes."""
    from repro.core.topology import TopologySpec
    from repro.sim.runner import _in_neighbors

    if family == "torus" and int(np.sqrt(n)) ** 2 != n:
        n = 16
    if family == "one-peer-exp":
        n = 1 << (n - 1).bit_length()  # power-of-two hypercube matchings
    if family in ("one-peer-ring", "random-match") and n % 2:
        n += 1
    spec = TopologySpec(family=family, seed=seed) if family == "random-match" \
        else TopologySpec(family=family)
    topo = spec.build(n)
    dense = _in_neighbors(topo)
    sparse = topo.in_neighbors()
    assert len(sparse) == topo.n
    for i in range(topo.n):
        assert set(sparse[i]) == dense[i], (family, n, i)
        assert list(sparse[i]) == sorted(sparse[i])
    # CSR form agrees with the tuple form
    indptr, indices = topo.in_neighbor_csr()
    for i in range(topo.n):
        assert list(indices[indptr[i]:indptr[i + 1]]) == list(sparse[i])


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 10_000),
    st.sampled_from([1, 2, 4, 8]),
    st.lists(st.sampled_from([1.0, 1.5, 2.0, 3.0]), min_size=8, max_size=8),
    st.booleans(),
    st.booleans(),
)
def test_event_engines_bit_exact_on_random_scenarios(
    seed, max_staleness, speeds, with_failstop, with_linkdeg
):
    """Vectorized vs per-node engine on *randomized* scenarios: arbitrary
    constant speed mixes (full ties, partial ties, no ties), random SSP
    bounds, optional fail-stop (reroute) and link degradation.  Full
    SimResult bit-equality — the generative version of the pinned registry
    parity test."""
    from repro.core import OptimizerConfig, make_optimizer
    from repro.sim import FailStop, LinkDegrade, Scenario, SimSpec, simulate
    from repro.sim.clock import ConstantDuration

    events = ()
    if with_failstop:
        events += (FailStop(at_step=4, nodes=(3,)),)
    if with_linkdeg:
        events += (LinkDegrade(at_step=3, edges=((0, 1), (5, 6)), delay=1.75),)
    sc = Scenario(
        name="rand", max_staleness=max_staleness, events=events,
        speeds=lambda n, _sp=tuple(speeds): [ConstantDuration(s) for s in _sp],
    )
    opt = make_optimizer(OptimizerConfig(algorithm="dmsgd", momentum=0.8))
    x0 = jnp.zeros((8, 5), jnp.float32)
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((8, 5, 5)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)

    def grad_fn(x, _s):
        return jnp.einsum("nij,nj->ni", A, x) + b

    kw = dict(topology="ring", n=8, n_steps=12, lr=1e-2, scenario=sc,
              seed=seed, record_dt=2.5)
    r1 = simulate(opt, SimSpec(engine="pernode", **kw), x0, grad_fn)
    r2 = simulate(opt, SimSpec(engine="vectorized", **kw), x0, grad_fn)
    assert bool(jnp.all(r1.params == r2.params))
    assert all(
        bool(jnp.all(a == b2)) for a, b2 in
        zip(jax.tree.leaves(r1.opt_state), jax.tree.leaves(r2.opt_state))
    )
    assert (r1.steps == r2.steps).all()
    assert (r1.stall_time == r2.stall_time).all()
    assert r1.sim_time == r2.sim_time
    assert r1.trace == r2.trace
    assert r1.events_log == r2.events_log


# ---------------------------------------------------------------------------
# Row-sparse gossip: random row-set sequences x delay x topology
# ---------------------------------------------------------------------------


@SET
@given(
    st.sampled_from(["ring", "exp", "one-peer-exp"]),
    st.integers(0, 2),
    st.integers(0, 2**31 - 1),
    st.booleans(),
)
def test_sparse_channel_random_rowsets_match_dense(name, delay, seed, all_dirty):
    """Generative form of the sparse-channel contract: random per-node
    touched-row sequences, with local updates supported exactly on the
    touched rows (consensus init, no decay — the regime exact tracking is
    sound in).  When every row is dirty, exact AND delta sparse outputs are
    bit-equal to the dense channel's every step, at every delay (delta:
    delay 0 only, by its own precondition).  Under random partial row sets,
    the exact trajectory matches dense to accumulation tolerance and rows
    no node ever touched keep their exact initial bits."""
    from repro.sparse import SparseStackedChannel

    n, R = 8, 6
    topo = build_topology(name, n)
    dense = DelayedStackedChannel(topo, delay)
    sparse = SparseStackedChannel(topo, delay)
    delta = SparseStackedChannel(topo, mode="delta") if delay == 0 else None
    rng = np.random.default_rng(seed)
    x0 = jnp.asarray(
        np.broadcast_to(rng.standard_normal((1, R)), (n, R)), jnp.float32
    )
    xd = xs = xdl = x0
    sd, ss = dense.init(x0), sparse.init(x0)
    sdl = delta.init(x0) if delta is not None else None
    never = np.ones(R, bool)
    for t in range(6):
        m = np.ones((n, R), bool) if all_dirty else rng.random((n, R)) < 0.3
        never &= ~m.any(axis=0)
        u = jnp.asarray(
            np.where(m, rng.standard_normal((n, R)), 0.0), jnp.float32
        )
        xd, xs = xd + u, xs + u
        sd, xd = dense.apply(sd, xd, jnp.int32(t))
        ss = sparse.mark(ss, jnp.asarray(m))
        ss, xs = sparse.apply(ss, xs, jnp.int32(t))
        if delta is not None:
            xdl = xdl + u
            sdl = delta.mark(sdl, jnp.asarray(m))
            sdl, xdl = delta.apply(sdl, xdl, jnp.int32(t))
        if all_dirty:
            np.testing.assert_array_equal(np.asarray(xd), np.asarray(xs))
            if delta is not None:
                np.testing.assert_array_equal(np.asarray(xd), np.asarray(xdl))
        else:
            np.testing.assert_allclose(
                np.asarray(xd), np.asarray(xs), rtol=1e-5, atol=1e-5
            )
    if not all_dirty and never.any():
        np.testing.assert_array_equal(
            np.asarray(xs)[:, never], np.asarray(x0)[:, never]
        )


# ---------------------------------------------------------------------------
# Resilient mixing: W-stochasticity under arbitrary fault masks
# ---------------------------------------------------------------------------


@SET
@given(
    st.sampled_from(["ring", "torus", "exp", "one-peer-exp", "full"]),
    st.lists(st.booleans(), min_size=8, max_size=8),
    st.integers(0, 7),
)
def test_healed_w_properties_any_fault_mask(name, alive, t):
    """The self-healing invariant (ISSUE 10): for ANY fault mask the
    effective mixing matrix stays row-stochastic with non-negative entries,
    reduces exactly to the static W when no faults fire, freezes dead rows
    to e_i with their columns zeroed, and — W being symmetric — keeps the
    surviving block doubly stochastic (DecentLaM's 1/lr bias correction
    divides by the row sum, so any deficiency would be amplified into the
    update)."""
    from repro.resilience import healed_W

    topo = build_topology(name, 8)
    a = np.asarray(alive, bool)
    t = t % topo.period
    W = np.asarray(topo.W(t), np.float64)
    Wh = healed_W(topo, t, a)
    np.testing.assert_allclose(Wh.sum(axis=1), 1.0, atol=1e-12)
    assert (Wh >= -1e-12).all()
    if a.all():
        np.testing.assert_array_equal(Wh, W)
    for i in np.flatnonzero(~a):
        assert Wh[i, i] == 1.0 and np.count_nonzero(Wh[i]) == 1
        assert np.count_nonzero(np.delete(Wh[:, i], i)) == 0
    # symmetric W => doubly stochastic over the survivor block
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    if a.any():
        np.testing.assert_allclose(Wh.sum(axis=0)[a], 1.0, atol=1e-12)


@SET
@given(
    st.sampled_from(["ring", "exp", "one-peer-exp"]),
    st.lists(st.booleans(), min_size=8, max_size=8),
    st.integers(0, 2**31 - 1),
)
def test_resilient_channel_equals_healed_w(name, alive, seed):
    """One healed round through the live channel is exactly ``healed_W @ x``
    for any trust mask, and with an all-true mask it is bit-exact with the
    unwrapped channel (no float is ever added on the clean path)."""
    from repro.resilience import ResilientChannel, healed_W, with_trust

    topo = build_topology(name, 8)
    a = np.asarray(alive, bool)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    res = ResilientChannel(StackedChannel(topo))
    st_r = with_trust(res.init(x), a)
    _, y = res.apply(st_r, x, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(y), healed_W(topo, 0, a) @ np.asarray(x, np.float64),
        atol=1e-5,
    )
    if a.all():
        _, y_plain = StackedChannel(topo).apply({}, x, jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_plain))


@SET
@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
def test_chaos_empty_schedule_bitexact_any_trajectory(seed, steps):
    """Property form of the PR gate: a ChaosChannel with an EMPTY schedule
    is bit-exact with the unwrapped channel over arbitrary random
    trajectories (the wrapper must be a pure delegate, not merely close)."""
    from repro.resilience import ChaosChannel, ChaosSchedule

    topo = build_topology("exp", 8)
    plain = StackedChannel(topo)
    chaos = ChaosChannel(StackedChannel(topo), ChaosSchedule())
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    sp, sc = plain.init(x), chaos.init(x)
    for t in range(steps):
        sp, yp = plain.apply(sp, x, jnp.int32(t))
        sc, yc = chaos.apply(sc, x, jnp.int32(t))
        np.testing.assert_array_equal(np.asarray(yp), np.asarray(yc))
        x = yp + jnp.asarray(rng.standard_normal(yp.shape), jnp.float32) * 0.1
