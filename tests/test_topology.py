import numpy as np
import pytest

from repro.core.topology import (
    build_topology,
    metropolis_weights,
    rho,
)

NS = [4, 8, 16]


@pytest.mark.parametrize("name", ["ring", "torus", "exp", "one-peer-exp", "random-match", "full"])
@pytest.mark.parametrize("n", NS)
def test_topology_valid(name, n):
    t = build_topology(name, n)
    t.validate()  # symmetric, doubly stochastic, classes reconstruct W
    assert t.n == n


@pytest.mark.parametrize("name", ["ring", "torus", "exp", "one-peer-exp", "random-match"])
def test_rho_in_unit_interval(name):
    t = build_topology(name, 16)
    r = t.rho()
    assert 0.0 < r < 1.0, r


def test_rho_ordering_matches_connectivity():
    # better-connected graphs have smaller rho (paper Sec. 4)
    ring = build_topology("ring", 16).rho()
    torus = build_topology("torus", 16).rho()
    exp = build_topology("exp", 16).rho()
    full = build_topology("full", 16).rho()
    assert full < exp < torus < ring


def test_one_peer_exponential_period():
    t = build_topology("one-peer-exp", 16)
    assert t.period == 4  # log2(16)
    for s in range(t.period):
        W = t.W(s)
        # perfect matching: every row has exactly one off-diagonal 1/2
        off = W - np.diag(np.diag(W))
        assert (np.count_nonzero(off, axis=1) == 1).all()
        assert np.allclose(off[off > 0], 0.5)


def test_random_match_seeded_deterministic():
    a = build_topology("random-match", 8, seed=3)
    b = build_topology("random-match", 8, seed=3)
    for s in range(a.period):
        np.testing.assert_array_equal(a.W(s), b.W(s))


@pytest.mark.parametrize("name", ["one-peer-exp", "random-match"])
def test_exclude_time_varying_per_phase(name):
    """Excluding nodes from a time-varying topology must hold per *phase*:
    every cycled W stays symmetric doubly stochastic, with zero weight to and
    from the dead nodes and the dead diagonal pinned at 1."""
    t = build_topology(name, 8)
    assert t.period > 1  # premise: actually time-varying
    dead = (2, 5)
    t2 = t.exclude(dead)
    assert t2.period == t.period  # the cycle structure survives exclusion
    t2.validate()  # symmetry + row stochasticity + classes == W, every phase
    alive = [i for i in range(8) if i not in dead]
    for phase in range(t2.period):
        W = t2.W(phase)
        np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)  # columns
        np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)  # rows
        for d in dead:
            assert W[d, d] == 1.0
            assert np.count_nonzero(W[d, :]) == 1  # sends nothing
            assert np.count_nonzero(W[:, d]) == 1  # receives nothing
        # survivor block is itself doubly stochastic per phase
        Ws = W[np.ix_(alive, alive)]
        np.testing.assert_allclose(Ws.sum(axis=1), 1.0, atol=1e-12)
        np.testing.assert_allclose(Ws.sum(axis=0), 1.0, atol=1e-12)
    # averaged over the period the survivors still mix
    Wbar = sum(t2.W(p) for p in range(t2.period)) / t2.period
    assert rho(Wbar[np.ix_(alive, alive)]) < 1.0


def test_exclude_time_varying_edge_classes_route_around_dead():
    """A dead node's partner in a matching phase falls back to self-weight 1
    (its payload has nowhere to go that phase)."""
    t = build_topology("one-peer-exp", 8)
    t2 = t.exclude([0])
    for phase in range(t2.period):
        W = t.W(phase)
        partner = int(np.nonzero(W[0])[0][np.nonzero(W[0])[0] != 0][0])
        W2 = t2.W(phase)
        assert W2[partner, partner] == 1.0  # widowed for this phase
        for c in t2.edge_classes(phase):
            assert c.recv_weight[0] == 0.0
            assert all(0 not in (src, dst) for src, dst in c.pairs)


def test_exclude_reroutes_and_stays_doubly_stochastic():
    t = build_topology("exp", 16)
    t2 = t.exclude([3, 7])
    t2.validate()
    W = t2.W(0)
    # dead nodes are isolated with self weight 1
    for d in (3, 7):
        assert W[d, d] == 1.0
        assert np.count_nonzero(W[d]) == 1
    # survivors still mix: spectral gap of the survivor block < 1
    alive = [i for i in range(16) if i not in (3, 7)]
    Ws = W[np.ix_(alive, alive)]
    assert rho(Ws) < 1.0
    np.testing.assert_allclose(Ws.sum(axis=1), 1.0, atol=1e-12)


def test_metropolis_irregular_graph():
    # star graph: strongly irregular degrees
    n = 6
    adj = np.zeros((n, n), np.int64)
    adj[0, 1:] = 1
    adj[1:, 0] = 1
    W = metropolis_weights(adj)
    np.testing.assert_allclose(W, W.T)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    assert (np.diag(W) >= 0).all()


def test_disconnected_is_identity():
    t = build_topology("none", 8)
    np.testing.assert_array_equal(t.W(0), np.eye(8))


# ---------------------------------------------------------------------------
# TopologySpec registry + sparse/time-varying generators (fleet PR)
# ---------------------------------------------------------------------------


def test_topology_spec_builds_and_validates():
    from repro.core.topology import TopologySpec

    t = TopologySpec(family="one-peer-exp", period=2).build(16)
    t.validate()
    assert t.period == 2  # truncated distance cycle
    with pytest.raises(ValueError, match="unknown topology family"):
        TopologySpec(family="warp")
    # params a family doesn't accept are an error, not silently dropped
    with pytest.raises(ValueError, match="does not take"):
        TopologySpec(family="ring", seed=3).build(8)


def test_build_topology_accepts_spec_string_and_passthrough():
    from repro.core.topology import Topology, TopologySpec

    via_spec = build_topology(TopologySpec(family="random-match", seed=3), 8)
    via_str = build_topology("random-match", 8, seed=3)
    for s in range(via_spec.period):
        np.testing.assert_array_equal(via_spec.W(s), via_str.W(s))
    # an already-built Topology passes straight through
    assert build_topology(via_spec, 8) is via_spec
    with pytest.raises(ValueError, match="built for n=8"):
        build_topology(via_spec, 16)  # n mismatch must not pass silently
    with pytest.raises(TypeError, match="factory kwargs"):
        build_topology(via_spec, 8, seed=3)


def test_one_peer_ring_matchings():
    t = build_topology("one-peer-ring", 8)
    t.validate()
    assert t.period == 2
    union = set()
    for s in range(t.period):
        W = t.W(s)
        off = W - np.diag(np.diag(W))
        # degree-1 matching per phase
        assert (np.count_nonzero(off, axis=1) == 1).all()
        union |= {(i, j) for i, j in zip(*np.nonzero(off))}
    # union over the period is the full ring
    ring = {(i, (i + 1) % 8) for i in range(8)} | {((i + 1) % 8, i) for i in range(8)}
    assert union == ring


def test_symmetric_exponential_degree_truncation():
    full = build_topology("exp", 16)
    trunc = build_topology("exp", 16, degree=2)
    off_full = np.count_nonzero(full.W(0) - np.diag(np.diag(full.W(0))), axis=1)
    off_trunc = np.count_nonzero(trunc.W(0) - np.diag(np.diag(trunc.W(0))), axis=1)
    assert (off_trunc < off_full).all()
    assert (off_trunc <= 4).all()  # +-2^0, +-2^1
    trunc.validate()
    assert trunc.rho() > full.rho()  # sparser graph mixes slower


def test_in_neighbor_csr_shapes():
    t = build_topology("one-peer-exp", 16)
    nbrs = t.in_neighbors()
    indptr, indices = t.in_neighbor_csr()
    assert indptr.shape == (17,) and indptr[0] == 0
    assert indptr[-1] == sum(len(x) for x in nbrs)
    assert all(len(nbrs[i]) == indptr[i + 1] - indptr[i] for i in range(16))
