"""Algorithm-level unit tests on the stacked reference harness."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OptimizerConfig,
    build_topology,
    make_linear_regression,
    make_optimizer,
    StackedChannel,
    make_stacked_mean,
    run_stacked,
)
from repro.core.optimizers import ALGORITHMS, _preprocess_grads, state_keys
from repro.core.update_spec import grad_scalars, math_ctx, reference_stage


def _run(algo, topo_name, *, n=8, steps=200, lr=1e-3, beta=0.9, het=1.0):
    prob = make_linear_regression(n=n, heterogeneity=het, seed=1)
    topo = build_topology(topo_name, n)
    # LARS' trust ratio is tuned for deep nets; on a raw quadratic the
    # default 1e-3 trust makes steps ~1000x smaller — scale it up so the
    # smoke criterion (loss decreases) is meaningful.
    extra = {"lars_trust": 0.05} if algo == "pmsgd-lars" else {}
    opt = make_optimizer(OptimizerConfig(algorithm=algo, momentum=beta, **extra))
    x0 = jnp.zeros((n, prob.dim), jnp.float32)
    params, _, _ = run_stacked(
        opt, topo, x0, lambda x, s: prob.grad(x), lr=lr, n_steps=steps
    )
    return prob, np.asarray(params)


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_every_algorithm_decreases_loss(algo):
    prob, x = _run(algo, "exp", steps=300)
    final = float(prob.loss(jnp.asarray(x.mean(axis=0))))
    init = float(prob.loss(jnp.zeros(prob.dim)))
    assert final < 0.2 * init, (algo, init, final)


@pytest.mark.parametrize("algo", ["decentlam", "dmsgd", "da-dmsgd"])
def test_full_topology_equals_pmsgd(algo):
    """With W = (1/n)11^T and consensus init, ATC decentralized momentum
    methods coincide with PmSGD exactly (DESIGN.md §5 invariant).  AWC is
    excluded: x+ = G(x) - lr*m keeps per-node momenta local, so replicas
    differ pointwise under heterogeneous data even with full averaging."""
    prob = make_linear_regression(n=4, heterogeneity=1.0, seed=0)
    topo = build_topology("full", 4)
    x0 = jnp.zeros((4, prob.dim), jnp.float32)

    def g(x, s):
        return prob.grad(x)

    opt_d = make_optimizer(OptimizerConfig(algorithm=algo, momentum=0.9))
    xd, _, _ = run_stacked(opt_d, topo, x0, g, lr=1e-3, n_steps=50)
    opt_p = make_optimizer(OptimizerConfig(algorithm="pmsgd", momentum=0.9))
    xp, _, _ = run_stacked(opt_p, topo, x0, g, lr=1e-3, n_steps=50)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xp), atol=2e-5)


def test_decentlam_beta0_equals_dsgd():
    prob = make_linear_regression(n=8, seed=2)
    topo = build_topology("ring", 8)
    x0 = jnp.zeros((8, prob.dim), jnp.float32)

    def g(x, s):
        return prob.grad(x)

    a, _, _ = run_stacked(
        make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.0)),
        topo, x0, g, lr=1e-3, n_steps=40,
    )
    b, _, _ = run_stacked(
        make_optimizer(OptimizerConfig(algorithm="dsgd")),
        topo, x0, g, lr=1e-3, n_steps=40,
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_state_keys_cover_all_algorithms():
    for algo in ALGORITHMS:
        cfg = OptimizerConfig(algorithm=algo)
        opt = make_optimizer(cfg)
        st = opt.init({"w": jnp.zeros((3,))})
        assert set(st.keys()) == set(state_keys(cfg)), algo


def test_grad_clip_bounds_update():
    cfg = OptimizerConfig(algorithm="dsgd", grad_clip=0.5)
    opt = make_optimizer(cfg)
    topo = build_topology("full", 2)
    gossip = StackedChannel(topo)
    mean = make_stacked_mean(2)
    x = jnp.zeros((2, 10), jnp.float32)
    big = 100.0 * jnp.ones((2, 10), jnp.float32)
    x2, _, _ = opt.step(
        x, big, opt.init(x), lr=1.0, step_idx=jnp.int32(0), gossip=gossip, mean=mean
    )
    # ||update|| <= lr * clip
    assert float(jnp.linalg.norm(x2)) <= 0.5 + 1e-5


def test_lars_trust_ratio_scaling():
    cfg = OptimizerConfig(algorithm="pmsgd-lars", momentum=0.0, lars_trust=0.01)
    opt = make_optimizer(cfg)
    topo = build_topology("full", 2)
    gossip = StackedChannel(topo)
    mean = make_stacked_mean(2)
    x = {"w": jnp.ones((2, 4), jnp.float32)}
    g = {"w": 1000.0 * jnp.ones((2, 4), jnp.float32)}
    x2, _, _ = opt.step(
        x, g, opt.init(x), lr=1.0, step_idx=jnp.int32(0), gossip=gossip, mean=mean
    )
    # LARS normalizes the huge gradient: step size = lr * trust * ||x||
    step_norm = float(jnp.linalg.norm(x["w"] - x2["w"]))
    expected = 0.01 * float(jnp.linalg.norm(x["w"]))
    assert abs(step_norm - expected) / expected < 1e-3


def test_weight_decay_shrinks_params():
    cfg = OptimizerConfig(algorithm="dmsgd", momentum=0.0, weight_decay=0.1)
    opt = make_optimizer(cfg)
    topo = build_topology("full", 2)
    gossip, mean = StackedChannel(topo), make_stacked_mean(2)
    x = jnp.ones((2, 4), jnp.float32)
    g = jnp.zeros((2, 4), jnp.float32)
    x2, _, _ = opt.step(
        x, g, opt.init(x), lr=0.1, step_idx=jnp.int32(0), gossip=gossip, mean=mean
    )
    np.testing.assert_allclose(np.asarray(x2), 1.0 - 0.1 * 0.1, rtol=1e-6)


def test_slowmo_syncs_to_consensus():
    cfg = OptimizerConfig(algorithm="slowmo", momentum=0.9, slowmo_period=5)
    opt = make_optimizer(cfg)
    prob = make_linear_regression(n=4, heterogeneity=2.0, seed=3)
    topo = build_topology("ring", 4)
    x0 = jnp.zeros((4, prob.dim), jnp.float32)
    params, _, _ = run_stacked(
        opt, topo, x0, lambda x, s: prob.grad(x), lr=1e-3, n_steps=5
    )
    # right after a sync step all nodes agree exactly
    x = np.asarray(params)
    np.testing.assert_allclose(x, np.broadcast_to(x[:1], x.shape), atol=1e-6)


def test_nesterov_matches_closed_form():
    """One step from zero momentum: nesterov update = lr*(1+b)*g."""
    cfg = OptimizerConfig(algorithm="dmsgd", momentum=0.9, nesterov=True)
    opt = make_optimizer(cfg)
    topo = build_topology("none", 2)  # identity gossip isolates the update
    gossip, mean = StackedChannel(topo), make_stacked_mean(2)
    x = jnp.zeros((2, 4), jnp.float32)
    g = jnp.ones((2, 4), jnp.float32)
    x2, st, _ = opt.step(
        x, g, opt.init(x), lr=0.1, step_idx=jnp.int32(0), gossip=gossip, mean=mean
    )
    np.testing.assert_allclose(np.asarray(x2), -0.1 * 1.9, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st["m"]), 1.0, rtol=1e-6)


def test_preprocess_grads_matches_fused_scalar_folding():
    """The fused stages fold clip/coupled-wd/LARS as scalars (grad_scalars +
    _g_eff); _preprocess_grads is the unfused semantic oracle — pin them."""
    cfg = OptimizerConfig(
        algorithm="dmsgd", momentum=0.9, weight_decay=0.05, grad_clip=0.5,
        lars=True, lars_trust=0.02,
    )
    rng = np.random.default_rng(11)
    x = {"a": jnp.asarray(rng.standard_normal((7, 3)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal(11), jnp.float32)}
    g = {"a": jnp.asarray(rng.standard_normal((7, 3)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal(11), jnp.float32)}

    want = _preprocess_grads(cfg, x, g)

    scalars = dict(grad_scalars(cfg, x, g))
    scalars["lr"] = jnp.float32(0.01)
    ctx = math_ctx(cfg, nesterov_ok=True, apply_decoupled_wd=False)
    got = reference_stage(
        "pre", "identity_g", ctx, {"x": x, "g": g}, scalars, x
    )["payload"]
    for k in x:
        np.testing.assert_allclose(
            np.asarray(want[k]), np.asarray(got[k]), rtol=1e-6, atol=1e-7
        )


def test_decentlam_sa_delay0_bit_exact_with_decentlam():
    """The acceptance pin: over any fresh transport (gap 0) decentlam-sa is
    decentlam, bit for bit — params AND momentum, multiple steps."""
    prob = make_linear_regression(n=8, seed=5)
    topo = build_topology("ring", 8)
    x0 = jnp.zeros((8, prob.dim), jnp.float32)

    def g(x, s):
        return prob.grad(x)

    p_sa, s_sa, _ = run_stacked(
        make_optimizer(OptimizerConfig(algorithm="decentlam-sa", momentum=0.9)),
        topo, x0, g, lr=1e-3, n_steps=60,
    )
    p_dl, s_dl, _ = run_stacked(
        make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.9)),
        topo, x0, g, lr=1e-3, n_steps=60,
    )
    np.testing.assert_array_equal(np.asarray(p_sa), np.asarray(p_dl))
    np.testing.assert_array_equal(np.asarray(s_sa["m"]), np.asarray(s_dl["m"]))


def test_decentlam_sa_nesterov_delay0_bit_exact():
    prob = make_linear_regression(n=4, seed=6)
    topo = build_topology("full", 4)
    x0 = jnp.zeros((4, prob.dim), jnp.float32)

    def g(x, s):
        return prob.grad(x)

    runs = {}
    for algo in ("decentlam-sa", "decentlam"):
        runs[algo] = run_stacked(
            make_optimizer(
                OptimizerConfig(algorithm=algo, momentum=0.9, nesterov=True)
            ),
            topo, x0, g, lr=1e-3, n_steps=30,
        )[0]
    np.testing.assert_array_equal(
        np.asarray(runs["decentlam-sa"]), np.asarray(runs["decentlam"])
    )


def test_decentlam_sa_converges_where_decentlam_diverges():
    """Stale mixing (delay-2 channel): decentlam's implicit gradient feeds
    staleness back through momentum and leaves the basin; decentlam-sa
    stays at baseline bias."""
    from repro.core.reference import bias_to_optimum
    from repro.sim import run_delayed

    prob = make_linear_regression(n=8, heterogeneity=1.0, seed=0)
    topo = build_topology("ring", 8)
    x0 = jnp.zeros((8, prob.dim), jnp.float32)

    def g(x, s):
        return prob.grad(x)

    p_dl, _, _ = run_delayed(
        make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8)),
        topo, x0, g, delay=2, lr=1e-3, n_steps=200,
    )
    bias_dl = float(bias_to_optimum(p_dl, prob.x_star))
    p_sa, _, _ = run_delayed(
        make_optimizer(OptimizerConfig(algorithm="decentlam-sa", momentum=0.8)),
        topo, x0, g, delay=2, lr=1e-3, n_steps=200,
    )
    bias_sa = float(bias_to_optimum(p_sa, prob.x_star))
    assert not (np.isfinite(bias_dl) and bias_dl < 1e3)  # the recorded failure
    assert np.isfinite(bias_sa) and bias_sa < 0.05


def test_staleness_damping_schedule():
    """gamma(0) == 1 exactly (the bit-exactness hinge), monotone
    non-increasing in the gap, floored by sa_floor."""
    from repro.core.update_spec import staleness_damping

    cfg = OptimizerConfig(algorithm="decentlam-sa", sa_damping=0.5)
    gaps = jnp.arange(0, 12)
    f = np.asarray(staleness_damping(cfg, gaps))
    assert f[0] == 1.0
    assert (np.diff(f) <= 0).all()
    np.testing.assert_allclose(f, 0.5 ** np.arange(12), rtol=1e-6)
    cfg_f = OptimizerConfig(algorithm="decentlam-sa", sa_damping=0.5, sa_floor=0.1)
    ff = np.asarray(staleness_damping(cfg_f, gaps))
    assert (ff >= 0.1 - 1e-7).all() and ff[0] == 1.0 and (np.diff(ff) <= 0).all()
    # no channel / legacy closure: unobservable staleness is treated fresh
    assert float(staleness_damping(cfg, None)) == 1.0
    # config validation
    with pytest.raises(AssertionError):
        OptimizerConfig(algorithm="decentlam-sa", sa_damping=0.0)


def test_nesterov_decentlam_converges():
    prob = make_linear_regression(n=8, seed=4)
    topo = build_topology("exp", 8)
    opt = make_optimizer(
        OptimizerConfig(algorithm="decentlam", momentum=0.9, nesterov=True)
    )
    x0 = jnp.zeros((8, prob.dim), jnp.float32)
    x, _, _ = run_stacked(
        opt, topo, x0, lambda xx, s: prob.grad(xx), lr=5e-4, n_steps=300
    )
    final = float(prob.loss(jnp.asarray(np.asarray(x).mean(axis=0))))
    assert final < 0.1 * float(prob.loss(jnp.zeros(prob.dim)))
