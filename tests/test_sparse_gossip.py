"""Row-sparse gossip: channels, tracker, and sim-engine integration.

The load-bearing claims pinned here:

* **all-dirty == dense, bit-exact** — for every algorithm, in both sparse
  modes: when every row is marked the sparse channel's trajectory is
  bit-identical to the dense channel's (exact mode selects the dense bits
  via ``where``; delta mode's hybrid falls back to the dense einsum).
* **clean rows are identity** — exact mode never touches a row no node
  marked; with genuinely sparse gradients on a dyadic-weight ring the
  whole trajectory stays bit-equal to dense gossip (mixing identical rows
  with dyadic weights is exact in binary floating point).
* **delta heals after delivery** — a marked row stays dirty per phase
  until that phase ships it, then is clean for those peers.
* **crossover** forces the dense fallback and dense-equivalent accounting.
* **byte accounting** equals the analytic row-count model.
* :class:`RowTracker` maps token ids / router hits to exactly the plane
  rows that hold them; unfed sources degrade to fully-dirty (conservative).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DelayedStackedChannel,
    OptimizerConfig,
    StackedChannel,
    build_topology,
    make_linear_regression,
    make_optimizer,
    make_stacked_mean,
    wire_bytes,
)
from repro.core.optimizers import ALGORITHMS
from repro.sparse import (
    RowTracker,
    SparseGossipChannel,
    SparseStackedChannel,
    build_sparse_channel,
    grad_row_masks,
)

N = 4


def _tree_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _run(channel, *, algo="decentlam", n_steps=5, mask_fn=None, seed=3,
         momentum=0.8, weight_decay=0.0, jit=True):
    """Stacked trajectory through ``opt.step`` with per-step mask marking.

    ``channel`` may be a factory ``opt -> channel`` (to pick up
    ``opt.gossips_per_step`` for multi-gossip algorithms).  ``mask_fn(step)
    -> (dim,) bool`` zeroes the gradient off-mask and marks exactly the
    touched rows; ``None`` runs dense grads + all-dirty marks.
    """
    prob = make_linear_regression(n=N, m=6, d=5, noise=0.01, seed=seed)
    opt = make_optimizer(OptimizerConfig(
        algorithm=algo, momentum=momentum, weight_decay=weight_decay,
    ))
    if callable(channel) and not hasattr(channel, "apply"):
        channel = channel(opt)
    mean = make_stacked_mean(N)
    sparse = isinstance(channel, SparseStackedChannel)

    def one(params, opt_state, chstate, k):
        grads = prob.grad(params)
        if mask_fn is not None:
            grads = jnp.where(mask_fn(k)[None, :], grads, 0.0)
        if sparse:
            chstate = channel.mark(chstate, grad_row_masks(grads))
        return opt.step(
            params, grads, opt_state, lr=jnp.float32(1e-2), step_idx=k,
            gossip=channel, mean=mean, comp_state=chstate,
        )

    if jit:
        one = jax.jit(one)
    params = jnp.asarray(
        np.random.default_rng(seed).standard_normal((N, prob.dim)), jnp.float32
    )
    # replicas start in consensus (the broadcast invariant exact mode needs)
    params = jnp.broadcast_to(params[:1], params.shape)
    opt_state = opt.init(params)
    chstate = channel.init(params)
    for k in range(n_steps):
        params, opt_state, chstate = one(params, opt_state, chstate, jnp.int32(k))
    return params, chstate


TOPO = build_topology("ring", N)


# ---------------------------------------------------------------------------
# all-dirty == dense: every algorithm, both modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("mode", ["exact", "delta"])
def test_all_dirty_bitexact_with_dense(algo, mode):
    dense, _ = _run(StackedChannel(TOPO), algo=algo)
    sparse, chstate = _run(
        lambda opt: SparseStackedChannel(
            TOPO, mode=mode, calls_per_step=opt.gossips_per_step
        ),
        algo=algo,
    )
    assert _tree_equal(dense, sparse), (algo, mode)
    vol = chstate["rows"]["vol"]
    # dense grads mark every row: accounting must report dense-equivalent
    np.testing.assert_allclose(
        np.asarray(vol["sparse"]), np.asarray(vol["dense"]), rtol=1e-6
    )


@pytest.mark.parametrize("mode", ["exact", "delta"])
def test_all_dirty_bitexact_with_compression(mode):
    dense, _ = _run(StackedChannel(TOPO, compression="int8"))
    sparse, _ = _run(SparseStackedChannel(TOPO, mode=mode, compression="int8"))
    assert _tree_equal(dense, sparse)


def test_all_dirty_bitexact_with_stateful_compression_exact():
    # int8-row EF residuals ride the row framing (exact mode only)
    dense, _ = _run(StackedChannel(TOPO, compression="int8-row-ef"))
    sparse, _ = _run(SparseStackedChannel(TOPO, compression="int8-row-ef"))
    assert _tree_equal(dense, sparse)


def test_delayed_all_dirty_bitexact_with_delayed_dense():
    dense, _ = _run(DelayedStackedChannel(TOPO, 2), n_steps=7)
    sparse, _ = _run(SparseStackedChannel(TOPO, 2), n_steps=7)
    assert _tree_equal(dense, sparse)


# ---------------------------------------------------------------------------
# exact mode: clean rows are identity / dyadic-ring trajectory equality
# ---------------------------------------------------------------------------


def _row_mask(k):
    # rows {0, 3} touched every step; row 4 from step 2 on; rest never
    base = jnp.asarray([True, False, False, True, False])
    return base | (jnp.arange(5) == 4) & (k >= 2)


def test_exact_partial_masks_trajectory_equals_dense():
    """With grads vanishing off-mask (wd=0), exact sparse gossip skips the
    clean rows entirely — they keep their initial bits — while the dense
    channel keeps re-mixing them (a no-op up to rounding: the einsum's
    ``0.5x + 0.25x + 0.25x`` accumulation can round mid-sum even on
    bit-identical rows).  So the claim is: sparse clean rows are
    bit-frozen, and the whole trajectory matches dense to accumulation
    tolerance — not bitwise, which even dense-vs-dense with a reordered
    sum would fail."""
    x0 = None
    for delay in (0, 2):
        dense_ch = DelayedStackedChannel(TOPO, delay)
        dense, _ = _run(dense_ch, n_steps=6, mask_fn=_row_mask)
        sp_ch = SparseStackedChannel(TOPO, delay)
        sparse, chstate = _run(sp_ch, n_steps=6, mask_fn=_row_mask)
        np.testing.assert_allclose(
            np.asarray(dense), np.asarray(sparse), rtol=2e-6, atol=2e-6,
            err_msg=f"delay={delay}",
        )
        if x0 is None:  # reconstruct the shared deterministic init
            x0 = np.broadcast_to(
                np.random.default_rng(3).standard_normal((N, 5))[:1], (N, 5)
            ).astype(np.float32)
        # rows 1, 2 are never touched: bit-frozen at their initial value
        np.testing.assert_array_equal(np.asarray(sparse)[:, 1:3], x0[:, 1:3])
        d = np.asarray(jax.tree.leaves(chstate["rows"]["dirty"])[0])
        np.testing.assert_array_equal(
            d[0], [True, False, False, True, True]
        )
        vol = chstate["rows"]["vol"]
        assert float(np.mean(vol["sparse"])) < float(np.mean(vol["dense"]))


def test_exact_mask_is_monotone_and_global():
    ch = SparseStackedChannel(TOPO)
    x = jnp.zeros((N, 5), jnp.float32)
    st = ch.init(x)
    # node 2 alone marks row 1; after one round every node's mask has it
    m = jnp.zeros((N, 5), bool).at[2, 1].set(True)
    st = ch.mark(st, m)
    st, _ = ch.apply(st, x, jnp.int32(0))
    np.testing.assert_array_equal(
        np.asarray(st["rows"]["dirty"]), np.broadcast_to(
            np.asarray([False, True, False, False, False]), (N, 5)
        ),
    )
    # no new marks: the mask never shrinks
    st, _ = ch.apply(st, x, jnp.int32(1))
    assert np.asarray(st["rows"]["dirty"])[0, 1]


# ---------------------------------------------------------------------------
# delta mode: per-phase heal-after-delivery
# ---------------------------------------------------------------------------


def test_delta_heals_per_phase():
    topo = build_topology("one-peer-exp", N)
    assert topo.period > 1
    ch = SparseStackedChannel(topo, mode="delta")
    x = jnp.zeros((N, 3), jnp.float32)
    st = ch.init(x)
    st = ch.mark(st, jnp.zeros((N, 3), bool).at[1, 2].set(True))
    st, _ = ch.apply(st, x, jnp.int32(0))
    d = np.asarray(st["rows"]["dirty"])  # (n, period, rows)
    assert not d[1, 0, 2], "phase 0 shipped -> healed for phase 0"
    assert d[1, 1:, 2].all(), "later phases still owed the row"
    st, _ = ch.apply(st, x, jnp.int32(1))
    assert not np.asarray(st["rows"]["dirty"])[1].any(), "all phases served"


def test_delta_rejects_delay_and_stateful_compression():
    with pytest.raises(ValueError, match="delay=0"):
        SparseStackedChannel(TOPO, 1, mode="delta")
    with pytest.raises(ValueError, match="stateless"):
        SparseStackedChannel(TOPO, mode="delta", compression="int8-row-ef")
    with pytest.raises(ValueError, match="top-k"):
        SparseStackedChannel(TOPO, compression="topk:0.1")
    with pytest.raises(ValueError, match="crossover"):
        SparseStackedChannel(TOPO, crossover=0.0)
    with pytest.raises(ValueError, match="mode"):
        SparseStackedChannel(TOPO, mode="topk")


# ---------------------------------------------------------------------------
# crossover: dense fallback
# ---------------------------------------------------------------------------


def test_crossover_forces_dense_fallback():
    """A tiny crossover makes every round ship dense: trajectory == dense
    channel bitwise even with sparse marks, and the accounting says dense."""
    dense, _ = _run(StackedChannel(TOPO), n_steps=4, mask_fn=_row_mask)
    sparse, chstate = _run(
        SparseStackedChannel(TOPO, crossover=1e-9), n_steps=4, mask_fn=_row_mask
    )
    assert _tree_equal(dense, sparse)
    vol = chstate["rows"]["vol"]
    np.testing.assert_allclose(
        np.asarray(vol["sparse"]), np.asarray(vol["dense"]), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------


def test_bytes_match_analytic_row_model():
    rows, lanes = 8, 16
    ch = SparseGossipChannel(TOPO, compression="int8")
    x = jnp.zeros((N, rows, lanes), jnp.float32)
    st = ch.init(x)
    hot = jnp.zeros((rows,), bool).at[jnp.asarray([1, 4, 6])].set(True)
    st = ch.mark(st, hot[None].repeat(N, 0))
    st, _ = ch.apply(st, x, jnp.int32(0))
    # ring phase 0: 2 sends; 3 rows x (int8 wire of 64B + 4B index)
    row_wire = wire_bytes(4.0 * lanes, "int8") + 4.0
    expected = 2 * 3 * row_wire
    np.testing.assert_allclose(
        np.asarray(st["rows"]["vol"]["sparse"]), expected, rtol=1e-6
    )
    got = ch.bytes_per_step(x[0].nbytes, st)
    assert got["egress_bytes"] == pytest.approx(expected)
    assert got["dense_egress_bytes"] == pytest.approx(
        2 * wire_bytes(4.0 * rows * lanes, "int8")
    )
    # analytic fallback (no state): dense upper bound
    assert ch.bytes_per_step(x[0].nbytes)["egress_bytes"] >= got["egress_bytes"]


def test_shipped_row_cost_capped_at_dense():
    # 1-lane rows: per-row index overhead would exceed dense; cap applies
    ch = SparseGossipChannel(TOPO)
    x = jnp.zeros((N, 8, 1), jnp.float32)
    st = ch.mark(ch.init(x), jnp.ones((N, 8), bool))
    st, _ = ch.apply(st, x, jnp.int32(0))
    vol = st["rows"]["vol"]
    np.testing.assert_allclose(np.asarray(vol["sparse"]), np.asarray(vol["dense"]))


# ---------------------------------------------------------------------------
# state plumbing
# ---------------------------------------------------------------------------


def test_state_specs_structure_matches_init():
    from jax.sharding import PartitionSpec as P

    tmpl = {"a": jnp.zeros((N, 6, 2)), "b": jnp.zeros((N,))}
    per_node = jax.tree.map(lambda x: x[0], tmpl)
    for mode in ("exact", "delta"):
        ch = build_sparse_channel(
            "ppermute", TOPO, ("data",), mode=mode, telemetry=True
        )
        st = ch.init(per_node)
        specs = ch.state_specs(jax.tree.map(lambda x: P(), per_node))
        is_p = lambda s: isinstance(s, P)  # noqa: E731
        assert jax.tree.structure(st) == jax.tree.structure(
            specs, is_leaf=is_p
        ), mode


def test_grad_row_masks_shapes_and_support():
    g = {
        "mat": jnp.zeros((N, 4, 3)).at[2, 1, 0].set(5.0),
        "vec": jnp.zeros((N,)).at[1].set(-1.0),
    }
    m = grad_row_masks(g)
    assert m["mat"].shape == (N, 4) and m["vec"].shape == (N, 1)
    assert np.asarray(m["mat"]).sum() == 1 and np.asarray(m["mat"])[2, 1]
    assert np.asarray(m["vec"]).sum() == 1 and np.asarray(m["vec"])[1, 0]


def test_mark_broadcasts_and_accepts_counts():
    ch = SparseGossipChannel(TOPO)
    x = jnp.zeros((N, 5), jnp.float32)
    st = ch.init(x)
    st = ch.mark(st, jnp.asarray([0, 2, 0, 0, 1], jnp.int32))  # counts, (R,)
    p = np.asarray(st["rows"]["pending"])
    assert p.shape == (N, 5)
    np.testing.assert_array_equal(p, np.broadcast_to(
        [False, True, False, False, True], (N, 5)
    ))


def test_build_sparse_channel_dispatch():
    assert isinstance(
        build_sparse_channel("stacked", TOPO), SparseStackedChannel
    )
    from repro.sparse import SparseDelayedPpermuteChannel, SparsePpermuteChannel

    assert isinstance(
        build_sparse_channel("ppermute", TOPO, ("d",)), SparsePpermuteChannel
    )
    assert isinstance(
        build_sparse_channel("ppermute", TOPO, ("d",), delay=2),
        SparseDelayedPpermuteChannel,
    )
    with pytest.raises(ValueError, match="exact"):
        build_sparse_channel("ppermute", TOPO, ("d",), delay=2, mode="delta")
    with pytest.raises(ValueError, match="node_axes"):
        build_sparse_channel("ppermute", TOPO)
    with pytest.raises(ValueError, match="unknown"):
        build_sparse_channel("allgather", TOPO, ("d",))


# ---------------------------------------------------------------------------
# RowTracker on the granite-moe SMOKE model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def moe_tracker():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.train.train_state import model_plane_layout

    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    layout = model_plane_layout(cfg, 1)
    tmpl = jax.eval_shape(lambda k: T.init_params(k, cfg, 1), jax.random.key(0))
    return cfg, layout, RowTracker.for_model(
        layout, tmpl, tied_embeddings=cfg.tie_embeddings
    )


def test_tracker_discovers_embed_and_moe_sources(moe_tracker):
    cfg, layout, tracker = moe_tracker
    names = set(tracker.source_names)
    assert "embed" in names
    assert any(n.startswith("moe/") for n in names)
    summ = tracker.summary()
    emb = [s for s in summ["sources"] if s["name"] == "embed"]
    assert emb and emb[0]["units"] == cfg.vocab_size
    moe = [s for s in summ["sources"] if s["kind"] == "moe"]
    # each slab source is (layers-in-group x experts) units
    assert all(s["units"] % cfg.n_experts == 0 for s in moe)


def test_tracker_token_ids_hit_exactly_their_rows(moe_tracker):
    from repro.core.planes import LANES

    cfg, layout, tracker = moe_tracker
    src = next(s for s in tracker.sources if s.name == "embed")
    tokens = jnp.asarray([[7, 7, 130]], jnp.int32)
    masks = tracker.step_masks(
        {"embed": tokens, **{
            n: np.zeros(next(s.units for s in tracker.sources if s.name == n))
            for n in tracker.source_names if n.startswith("moe/")
        }}
    )
    got = np.asarray(masks[src.bucket])[
        src.row_start: src.row_start + src.rows
    ]
    # reference: element-interval overlap computed densely
    want = np.zeros(src.rows, bool)
    for u in (7, 130):
        lo, hi = u * src.unit_size, (u + 1) * src.unit_size
        want[lo // LANES: (hi - 1) // LANES + 1] = True
    np.testing.assert_array_equal(got, want)
    # an unfed moe source would be fully dirty; fed-empty stays clean
    moe_src = next(s for s in tracker.sources if s.kind == "moe")
    moe_rows = np.asarray(masks[moe_src.bucket])[
        moe_src.row_start: moe_src.row_start + moe_src.rows
    ]
    assert not moe_rows.any()


def test_tracker_missing_source_is_fully_dirty(moe_tracker):
    cfg, layout, tracker = moe_tracker
    masks = tracker.step_masks({})  # nothing fed -> conservative
    for key in layout.segments:
        covered = np.zeros(layout.rows[key], bool)
        for seg in layout.segments[key]:
            covered[seg.row_start: seg.row_start + seg.rows] = True
        got = np.asarray(masks[key])
        np.testing.assert_array_equal(got, covered, err_msg=key)
    assert _tree_equal(masks, tracker.all_dirty())


def test_tracker_pad_rows_stay_clean(moe_tracker):
    cfg, layout, tracker = moe_tracker
    masks = tracker.step_masks({})
    for key in layout.segments:
        got = np.asarray(masks[key])
        pad = np.ones(layout.rows[key], bool)
        for seg in layout.segments[key]:
            pad[seg.row_start: seg.row_start + seg.rows] = False
        assert not got[pad].any(), key


def test_tracker_dense_leaves_always_base_dirty(moe_tracker):
    cfg, layout, tracker = moe_tracker
    # feed everything empty: only the dense base + nothing sparse
    units = {"embed": jnp.zeros((1,), jnp.int32).at[0].set(-1)}  # oob -> drop
    units.update({
        n: np.zeros(next(s.units for s in tracker.sources if s.name == n))
        for n in tracker.source_names if n.startswith("moe/")
    })
    masks = tracker.step_masks(units)
    summ = tracker.summary()
    for key, info in summ["buckets"].items():
        base = int(np.asarray(masks[key]).sum())
        # all dirty rows are exactly the dense base (sparse sources clean,
        # except the oob token which drops)
        assert base <= info["base_dirty_rows"] + 1, key


def test_tracker_rejects_bad_hit_mask_size(moe_tracker):
    cfg, layout, tracker = moe_tracker
    moe_name = next(n for n in tracker.source_names if n.startswith("moe/"))
    with pytest.raises(ValueError, match="units"):
        tracker.step_masks({moe_name: np.zeros(3, np.float32)})


def test_tracker_sharded_layout_slices_rank_block():
    """On a sharded layout the touch inputs stay GLOBAL (token ids over the
    full vocab, router hits over all experts) and ``step_masks(...,
    shard_rank=r)`` lights exactly rank r's local rows; without
    ``shard_rank`` it refuses."""
    from jax.sharding import PartitionSpec as P

    from repro.core.planes import LANES, PlaneLayout

    tp, vocab, d = 2, 64, 512  # local: 32 vocab units x 512 = 16 rows/rank
    lg, ne, dm, df = 1, 4, 96, 352  # expert unit = 96*352 elements
    tmpl = {
        "embed": {"table": jnp.zeros((vocab, d), jnp.float32)},
        "groups": {"g0": {"moe": {
            "w_in": jnp.zeros((lg, ne, dm, df), jnp.float32),
        }}},
        "final_norm": {"scale": jnp.zeros((d,), jnp.float32)},
    }
    specs = {
        "embed": {"table": P("model", None)},
        "groups": {"g0": {"moe": {"w_in": P(None, "model", None, None)}}},
        "final_norm": {"scale": None},
    }
    layout = PlaneLayout.build(tmpl, tp=tp, shardings=specs)
    tracker = RowTracker.for_model(layout, tmpl, tied_embeddings=False)

    emb = next(s for s in tracker.sources if s.name == "embed")
    moe = next(s for s in tracker.sources if s.kind == "moe")
    assert emb.unit_grid == (vocab,) and emb.shard_parts == tp
    assert emb.units == vocab // tp  # local
    assert moe.unit_grid == (lg, ne) and moe.shard_dim == 1
    assert moe.units == lg * ne // tp

    with pytest.raises(ValueError, match="shard_rank"):
        tracker.step_masks({"embed": jnp.zeros((1,), jnp.int32)})

    # global touches: tokens 3 and 40 (rank 0 / rank 1), expert 2 (rank 1)
    hits = np.zeros((lg, ne), np.float32)
    hits[0, 2] = 1.0
    units = {"embed": jnp.asarray([3, 40], jnp.int32),
             "moe/g0": jnp.asarray(hits)}
    for rank in range(tp):
        masks = tracker.step_masks(units, shard_rank=jnp.int32(rank))
        got = np.asarray(masks[emb.bucket])[
            emb.row_start: emb.row_start + emb.rows
        ]
        want = np.zeros(emb.rows, bool)
        for tok in (3, 40):
            lo = tok - rank * (vocab // tp)
            if 0 <= lo < vocab // tp:
                a, b = lo * emb.unit_size, (lo + 1) * emb.unit_size
                want[a // LANES: (b - 1) // LANES + 1] = True
        np.testing.assert_array_equal(got, want, err_msg=f"embed rank {rank}")

        got_moe = np.asarray(masks[moe.bucket])[
            moe.row_start: moe.row_start + moe.rows
        ]
        # expert 2 lives on rank 1 (local unit 0 there)
        want_moe = np.zeros(moe.rows, bool)
        if rank == 1:
            a, b = 0, moe.unit_size
            want_moe[a // LANES: (b - 1) // LANES + 1] = True
        np.testing.assert_array_equal(
            got_moe, want_moe, err_msg=f"moe rank {rank}"
        )
        # the replicated dense leaf is base-dirty on every rank
        norm_seg = next(
            seg for segs in layout.segments.values() for seg in segs
            if seg.index == 1  # final_norm/scale in dict flatten order
        )
        assert np.asarray(masks["float32"])[
            norm_seg.row_start: norm_seg.row_start + norm_seg.rows
        ].all()


# ---------------------------------------------------------------------------
# sim integration (condensed engine pins; the full matrix lives in test_sim)
# ---------------------------------------------------------------------------


def _sim(engine, sparse, gfn, **kw):
    from repro.sim import SimSpec, simulate

    opt = make_optimizer(OptimizerConfig(algorithm="decentlam", momentum=0.8))
    spec = SimSpec(topology="ring", n=8, lr=1e-2, n_steps=12, seed=0,
                   engine=engine, sparse=sparse, **kw)
    x0 = jnp.zeros((8, 12), jnp.float32)
    return simulate(opt, spec, x0, gfn)


_A = None


def _mk_grads():
    global _A
    if _A is None:
        key = jax.random.key(0)
        _A = (jax.random.normal(key, (8, 12, 12)) * 0.1 + jnp.eye(12),
              jax.random.normal(jax.random.key(1), (8, 12)))

    def dense(params, step):
        A, b = _A
        return jnp.einsum("nij,nj->ni", A, params) - b

    def sparse(params, step):
        rows = (jnp.arange(12)[None, :] + jnp.asarray(step)) % 3 == 0
        return jnp.where(rows, dense(params, step), 0.0)

    return dense, sparse


def test_sim_all_dirty_sparse_equals_dense_both_engines():
    dense_g, _ = _mk_grads()
    for engine in ("pernode", "vectorized"):
        rd = _sim(engine, None, dense_g)
        rs = _sim(engine, "exact", dense_g)
        assert _tree_equal(rd.params, rs.params), engine
        assert rs.comm is not None and rd.comm is None


@pytest.mark.parametrize("mode", ["exact", "delta"])
def test_sim_engines_bit_equal_under_sparse_grads(mode):
    _, sparse_g = _mk_grads()
    rp = _sim("pernode", mode, sparse_g)
    rv = _sim("vectorized", mode, sparse_g)
    assert _tree_equal(rp.params, rv.params), mode
    # pernode additionally models mailbox row-delta compaction
    assert rp.comm["wire_sparse_bytes"] < rp.comm["wire_dense_bytes"]
    assert rp.comm["mailbox_bytes"] < rp.comm["mailbox_dense_bytes"]
    assert "mailbox_bytes" not in rv.comm


def test_sim_delayed_engine_composes_with_sparse():
    dense_g, _ = _mk_grads()
    r = _sim("pernode", "exact", dense_g, scenario="stale_gossip_k2")
    assert r.comm["gossip_rounds"] > 0
