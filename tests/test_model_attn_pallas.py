"""The model's attention layer routed through the Pallas flash kernel
(interpret mode) must match the jnp chunked path."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models import transformer as T
from repro.models.layers import TPContext

TP1 = TPContext(size=1)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "olmo-1b", "h2o-danube-1.8b"])
def test_forward_loss_pallas_matches_jnp(arch):
    cfg = SMOKES[arch]
    params = T.init_params(jax.random.key(0), cfg, tp=1)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
    }
    outs = {}
    for impl in ("jnp", "pallas_interpret"):
        rt = T.RuntimeConfig(dtype="float32", remat=False, attn_impl=impl)
        loss, _ = jax.jit(lambda p, b: T.forward_loss(p, b, cfg, TP1, rt))(
            params, batch
        )
        outs[impl] = float(loss)
    assert abs(outs["jnp"] - outs["pallas_interpret"]) < 1e-4, outs
